"""§Roofline: aggregate the dry-run JSON artifacts into the per-(arch x shape
x mesh) three-term roofline table (EXPERIMENTS.md reads this output)."""
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(path=DRYRUN_DIR):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(report):
    recs = load_records()
    if not recs:
        report.note("no dry-run artifacts yet: run "
                    "`python -m repro.launch.dryrun --all --both-meshes`")
        return
    report.section("SS-Roofline: three-term roofline per (arch x shape x mesh)")
    ok = skipped = failed = 0
    for r in recs:
        name = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skipped":
            skipped += 1
            report.row("roofline", name, status="skipped")
            continue
        if r.get("status") != "ok":
            failed += 1
            report.row("roofline", name, status="FAILED")
            continue
        ok += 1
        report.row(
            "roofline", name,
            t_compute_ms=round(r["t_compute"] * 1e3, 2),
            t_memory_ms=round(r["t_memory"] * 1e3, 2),
            t_collective_ms=round(r["t_collective"] * 1e3, 2),
            bottleneck=r["bottleneck"],
            useful_pct=round(r["useful_flops_ratio"] * 100, 1),
            roofline_pct=round(r["roofline_fraction"] * 100, 2),
            hbm_gb=r["hbm_per_chip_gb"],
            fits=r["fits_hbm"])
    report.note(f"cells: {ok} ok, {skipped} skipped, {failed} failed")
