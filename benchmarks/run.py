"""Benchmark harness — compatibility shim over ``repro.bench``.

    PYTHONPATH=src python -m benchmarks.run [--only fig3] [--json out.json]
    PYTHONPATH=src python -m benchmarks.run --list

The measurement machinery lives in ``repro.bench`` (one timing protocol,
declarative scenarios, schema-versioned results); this module keeps the
historical entry point and flags working.  Output: ``section`` headers +
``name,us_per_call,derived...`` CSV rows; ``--json`` additionally writes
every row machine-readable in the schema-v2 ``BENCH_*.json`` trajectory
format.  With ``--json -`` the JSON goes to stdout and ALL progress/CSV
moves to stderr, so the stream parses cleanly.

Prefer ``python -m repro.bench.cli {list,run,sweep}`` for scenario-level
control (``--kernel``, ``--strategy``, ``--chip``, ``--smoke``).
"""
import argparse
import sys
import time

from repro.bench import results as bench_results

#: kept for backward compatibility; the payload is now the repro.bench
#: result schema.
REPORT_SCHEMA_VERSION = bench_results.SCHEMA_VERSION


class Report:
    """Streaming CSV reporter, now backed by the repro.bench result schema.

    Legacy callers use ``row()`` (free-form metrics); scenario-based
    benchmarks hand native ``BenchResult`` rows to ``add_result``.  Both
    end up in one schema-v2 payload.
    """

    def __init__(self, stream=None):
        self.stream = stream if stream is not None else sys.stdout
        self.rows = []                  # legacy (table, name, kv, section)
        self.results = []               # native BenchResult rows
        self._section = ""

    def section(self, title):
        self._section = title
        print(f"\n## {title}", file=self.stream, flush=True)

    def note(self, text):
        print(f"# NOTE: {text}", file=self.stream, flush=True)

    def row(self, table, name, **kv):
        parts = [f"{k}={v}" for k, v in kv.items()]
        print(f"{table},{name}," + ",".join(parts), file=self.stream,
              flush=True)
        self.rows.append((table, name, kv, self._section))

    def add_result(self, result):
        """Record a native BenchResult and echo its CSV line."""
        self.results.append(result)
        m = result.metrics
        kv = {k: m[k] for k in ("us_median", "us_mean", "us_min", "max_err",
                                "predicted_us") if k in m}
        parts = [f"strategy={result.strategy}",
                 f"config_source={result.config_source}"]
        parts += [f"{k}={round(v, 4) if isinstance(v, float) else v}"
                  for k, v in kv.items()]
        print(f"{result.section or 'bench'},{result.scenario},"
              + ",".join(parts), file=self.stream, flush=True)

    def to_json(self) -> dict:
        report = bench_results.BenchReport()
        for t, n, kv, s in self.rows:
            report.add(bench_results.upgrade_v1_row(
                {"table": t, "name": n, "section": s, "metrics": kv}))
        report.extend(self.results)
        try:
            import jax
            report.jax_version = jax.__version__
            report.backend = jax.default_backend()
        except Exception:
            pass
        return report.to_dict()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark module names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all Report rows as schema-v2 JSON to "
                         "PATH ('-' for stdout; progress moves to stderr)")
    ap.add_argument("--list", action="store_true",
                    help="print benchmark modules + registered scenarios "
                         "and exit without running anything")
    args = ap.parse_args(argv)

    from . import (bench_async_apps, bench_async_micro, bench_autotune,
                   bench_balance, bench_generations, roofline_table)
    benches = [
        ("bench_balance(Fig1+S6)", bench_balance.run),
        ("bench_generations(Fig2)", bench_generations.run),
        ("bench_async_micro(Fig3)", bench_async_micro.run),
        ("bench_async_apps(Fig4)", bench_async_apps.run),
        ("roofline_table(SSRoofline)", roofline_table.run),
        ("bench_autotune(Tuning)", bench_autotune.run),
    ]

    if args.list:
        from repro.bench import cli as bench_cli
        print("benchmark modules (--only filters these):")
        for name, _ in benches:
            print(f"  {name}")
        print("\nregistered repro.bench scenarios:")
        return bench_cli.main(["list"])

    # with --json - the payload owns stdout; everything else goes to stderr
    stream = sys.stderr if args.json == "-" else sys.stdout
    report = Report(stream=stream)
    t00 = time.time()
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n==== {name} ====", file=stream, flush=True)
        t0 = time.time()
        fn(report)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=stream,
              flush=True)
    print(f"\n# all benchmarks done in {time.time()-t00:.1f}s", file=stream)
    if args.json:
        import json
        payload = report.to_json()
        n_rows = len(payload["rows"])
        if args.json == "-":
            json.dump(payload, sys.stdout, indent=1)
            sys.stdout.write("\n")
        else:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"# wrote {n_rows} rows to {args.json}", file=stream)


if __name__ == "__main__":
    main()
