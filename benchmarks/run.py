"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3]

Output: ``section`` headers + ``name,us_per_call,derived...`` CSV rows.
"""
import argparse
import sys
import time


class Report:
    def __init__(self):
        self.rows = []

    def section(self, title):
        print(f"\n## {title}", flush=True)

    def note(self, text):
        print(f"# NOTE: {text}", flush=True)

    def row(self, table, name, **kv):
        parts = [f"{k}={v}" for k, v in kv.items()]
        print(f"{table},{name}," + ",".join(parts), flush=True)
        self.rows.append((table, name, kv))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark module names")
    args = ap.parse_args(argv)

    from . import (bench_async_apps, bench_async_micro, bench_balance,
                   bench_generations, roofline_table)
    benches = [
        ("bench_balance(Fig1+S6)", bench_balance.run),
        ("bench_generations(Fig2)", bench_generations.run),
        ("bench_async_micro(Fig3)", bench_async_micro.run),
        ("bench_async_apps(Fig4)", bench_async_apps.run),
        ("roofline_table(SSRoofline)", roofline_table.run),
    ]
    report = Report()
    t00 = time.time()
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n==== {name} ====", flush=True)
        t0 = time.time()
        fn(report)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    print(f"\n# all benchmarks done in {time.time()-t00:.1f}s")


if __name__ == "__main__":
    main()
