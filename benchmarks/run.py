"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3] [--json out.json]

Output: ``section`` headers + ``name,us_per_call,derived...`` CSV rows to
stdout; ``--json`` additionally writes every Report row machine-readable
(the feed format for the tuning registry and BENCH_*.json trajectories).
"""
import argparse
import json
import sys
import time

REPORT_SCHEMA_VERSION = 1


class Report:
    def __init__(self):
        self.rows = []
        self._section = ""

    def section(self, title):
        self._section = title
        print(f"\n## {title}", flush=True)

    def note(self, text):
        print(f"# NOTE: {text}", flush=True)

    def row(self, table, name, **kv):
        parts = [f"{k}={v}" for k, v in kv.items()]
        print(f"{table},{name}," + ",".join(parts), flush=True)
        self.rows.append((table, name, kv, self._section))

    def to_json(self) -> dict:
        return {
            "schema_version": REPORT_SCHEMA_VERSION,
            "rows": [{"table": t, "name": n, "section": s, "metrics": kv}
                     for t, n, kv, s in self.rows],
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark module names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all Report rows as JSON to PATH "
                         "('-' for stdout)")
    args = ap.parse_args(argv)

    from . import (bench_async_apps, bench_async_micro, bench_autotune,
                   bench_balance, bench_generations, roofline_table)
    benches = [
        ("bench_balance(Fig1+S6)", bench_balance.run),
        ("bench_generations(Fig2)", bench_generations.run),
        ("bench_async_micro(Fig3)", bench_async_micro.run),
        ("bench_async_apps(Fig4)", bench_async_apps.run),
        ("roofline_table(SSRoofline)", roofline_table.run),
        ("bench_autotune(Tuning)", bench_autotune.run),
    ]
    report = Report()
    t00 = time.time()
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n==== {name} ====", flush=True)
        t0 = time.time()
        fn(report)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    print(f"\n# all benchmarks done in {time.time()-t00:.1f}s")
    if args.json:
        payload = report.to_json()
        if args.json == "-":
            json.dump(payload, sys.stdout, indent=1)
            sys.stdout.write("\n")
        else:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"# wrote {len(payload['rows'])} rows to {args.json}")


if __name__ == "__main__":
    main()
