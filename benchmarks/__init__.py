# Benchmark suite: one module per paper table/figure (Fig 1/2/3/4) plus the
# roofline aggregation over the dry-run artifacts.
