"""Paper Fig. 3: the asynchronous-copy microbenchmark.

The measured half is declared, not hand-rolled: the ``fig3/*`` scenarios in
``repro.bench.scenario`` (stream kernel x strategy x intensity) run through
``repro.bench.runner`` — canonical timing, oracle check, full provenance —
and land in the report as native schema-v2 rows.  The analytic half
reproduces the paper's Fig 3a conclusions (async helps when memory-bound,
hurts when compute-bound) via the roofline-positioned strategy model for
the TPU target.
"""
from repro.bench import runner, scenario
from repro.core import hardware
from repro.core.async_pipeline import Strategy
from repro.kernels.stream import stream_flops_bytes

# TPU-target model: async copy overlaps DMA with compute; sync does not.
# sync:     t = t_dma + t_compute                (serialised)
# overlap:  t = max(t_dma, t_compute) + pipeline fill
# register_bypass: sync minus the staging pass through VMEM
# drop_off: overlap at chunk granularity (smaller fill, more per-chunk
#           issue overhead)
# The single implementation lives in repro.tuning.search_space so the
# benchmark's "expectation" and the autotuner's pruning can never diverge.

def model_time(strategy: Strategy, flops: float, nbytes: float,
               depth: int = 2, n_tiles: int = 64) -> float:
    from repro.tuning.search_space import predict_time
    return predict_time(strategy, flops, nbytes, depth=depth,
                        n_tiles=n_tiles, chip=hardware.TARGET)


def run(report):
    report.section("Fig3a: TPU-target roofline model, speedup of each async "
                   "strategy over sync vs arithmetic intensity")
    shape = (1 << 14, 256)          # 16 MiB working set per sweep point
    for iters in (1, 4, 16, 64, 256, 1024):
        flops, nbytes = stream_flops_bytes(shape, iters)
        intensity = flops / nbytes
        t_sync = model_time(Strategy.SYNC, flops, nbytes)
        row = {"intensity": round(intensity, 2)}
        for s in Strategy:
            row[s.value] = round(t_sync / model_time(s, flops, nbytes), 3)
        report.row("fig3a", f"iters={iters}", **row)
    report.note("model reproduces the paper: overlap ~1.3-1.5x when "
                "memory-bound, converging to ~1x (and below, with issue "
                "overhead) once compute-bound; pipeline (deeper overlap) "
                "degrades most gracefully")

    report.section("Fig3d: low-occupancy analogue — single- vs multi-buffered"
                   " under a VMEM budget")
    flops, nbytes = stream_flops_bytes(shape, 4)
    base = model_time(Strategy.OVERLAP, flops, nbytes, depth=2)
    for depth, tiles in ((1, 8), (2, 8), (2, 64), (4, 64)):
        s = Strategy.SYNC if depth == 1 else Strategy.OVERLAP
        t = model_time(s, flops, nbytes, depth=max(depth, 2), n_tiles=tiles)
        report.row("fig3d", f"depth={depth},tiles={tiles}",
                   rel_time=round(t / base, 3))

    report.section("Fig3 functional sweep: fig3/* scenarios (Pallas "
                   "interpret) — correctness + host us/call")
    opts = runner.RunOptions(warmup=1, repeats=3, emit=report.add_result)
    bench = runner.run_scenarios(scenario.scenarios(tag="fig3"), opts)
    assert all(r.metrics["check_ok"] for r in bench.results)
