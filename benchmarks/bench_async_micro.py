"""Paper Fig. 3: the asynchronous-copy microbenchmark.

Runs the actual Pallas stream kernel (interpret mode) across arithmetic
intensities and strategies, reporting per-call wall time on this host (a
functional-correctness sweep) AND the roofline-positioned analytic model for
the TPU target, which is where the paper's Fig 3a conclusions (async helps
when memory-bound, hurts when compute-bound) are reproduced quantitatively.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import balance, hardware
from repro.core.async_pipeline import Strategy
from repro.kernels import ops
from repro.kernels.stream import stream_flops_bytes

# TPU-target model: async copy overlaps DMA with compute; sync does not.
# sync:     t = t_dma + t_compute                (serialised)
# overlap:  t = max(t_dma, t_compute) + pipeline fill
# register_bypass: sync minus the staging pass through VMEM
# drop_off: overlap at chunk granularity (smaller fill, more per-chunk
#           issue overhead)
# The single implementation lives in repro.tuning.search_space so the
# benchmark's "expectation" and the autotuner's pruning can never diverge.

def model_time(strategy: Strategy, flops: float, nbytes: float,
               depth: int = 2, n_tiles: int = 64) -> float:
    from repro.tuning.search_space import predict_time
    return predict_time(strategy, flops, nbytes, depth=depth,
                        n_tiles=n_tiles, chip=hardware.TARGET)


def run(report):
    report.section("Fig3a: TPU-target roofline model, speedup of each async "
                   "strategy over sync vs arithmetic intensity")
    shape = (1 << 14, 256)          # 16 MiB working set per sweep point
    for iters in (1, 4, 16, 64, 256, 1024):
        flops, nbytes = stream_flops_bytes(shape, iters)
        intensity = flops / nbytes
        t_sync = model_time(Strategy.SYNC, flops, nbytes)
        row = {"intensity": round(intensity, 2)}
        for s in Strategy:
            row[s.value] = round(t_sync / model_time(s, flops, nbytes), 3)
        report.row("fig3a", f"iters={iters}", **row)
    report.note("model reproduces the paper: overlap ~1.3-1.5x when "
                "memory-bound, converging to ~1x (and below, with issue "
                "overhead) once compute-bound; pipeline (deeper overlap) "
                "degrades most gracefully")

    report.section("Fig3d: low-occupancy analogue — single- vs multi-buffered"
                   " under a VMEM budget")
    flops, nbytes = stream_flops_bytes(shape, 4)
    base = model_time(Strategy.OVERLAP, flops, nbytes, depth=2)
    for depth, tiles in ((1, 8), (2, 8), (2, 64), (4, 64)):
        s = Strategy.SYNC if depth == 1 else Strategy.OVERLAP
        t = model_time(s, flops, nbytes, depth=max(depth, 2), n_tiles=tiles)
        report.row("fig3d", f"depth={depth},tiles={tiles}",
                   rel_time=round(t / base, 3))

    report.section("Fig3 functional sweep: Pallas kernel (interpret) "
                   "correctness + host us/call")
    x = jax.random.uniform(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    for strategy in Strategy:
        for iters in (1, 32):
            fn = lambda: ops.stream(x, iters=iters, strategy=strategy,
                                    tile_rows=16, n_tiles=8)
            out = fn()
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            us = (time.perf_counter() - t0) * 1e6
            report.row("fig3_functional",
                       f"{strategy.value}/iters={iters}",
                       us_per_call=round(us, 1),
                       max_err=float(jnp.max(jnp.abs(
                           out - (0.5 ** iters * x + (1 - 0.5 ** iters))))))
