"""Paper Fig. 2 analogue: the lineage study.

No physical GPUs exist in this container, so the reproduction is the paper's
own methodology applied analytically: each benchmark kernel is characterised
by its arithmetic intensity (flops/byte), and per-chip execution time is the
2-term roofline estimate.  We validate the model against the paper's measured
generation-to-generation speedups and extend the lineage with TPUs.

The kernel suite is OUR Pallas implementations' analytic (flops, bytes) at
the paper's input sizes (Table 2).  The per-scenario version of this sweep
— actual Pallas shapes, resolved (possibly tuned) configs, one model row
per registered Chip — is ``python -m repro.bench.cli sweep``; this module
keeps the paper-sized Table 2 suite, which is too big to *measure* in
interpret mode.
"""
import math

from repro.core import balance, hardware

# (name, flops, bytes, intensity-class) at paper Table 2 inputs (fp32)
# flops/bytes derived from the kernels' analytic models
def _suite():
    suite = []
    # hotspot: 8192^2 grid, 5 iter, ~10 flops/cell, 2 reads + 1 write
    n = 8192 * 8192
    suite.append(("hotspot", 10.0 * n * 5, (3 * 4.0) * n * 5))
    # pathfinder: 100000x10000, ~4 ops/cell, 1 read + small state
    n = 100000 * 10000
    suite.append(("pathfinder", 4.0 * n, 4.0 * n + 8.0 * 10000 * 100000 / 1000))
    # NW: 16384^2 cells, ~6 flops/cell (max-plus scan), 1 read 1 write
    n = 16384 * 16384
    suite.append(("nw", 6.0 * n, 8.0 * n))
    # LUD: 16384^3 * 2/3 flops, O(n^2 * n/bs) bytes at bs=128
    n = 16384
    suite.append(("lud", (2 / 3) * n ** 3 * 2, 4.0 * n * n * (n / 128) * 2))
    # stream microbenchmark at low/high intensity (paper Fig 3)
    n = 2 * 2 ** 30 / 4
    suite.append(("stream_lo", 2.0 * n * 1, 8.0 * n))
    suite.append(("stream_hi", 2.0 * n * 256, 8.0 * n))
    # backprop-like (two dense layers, 2^20 x 16)
    suite.append(("backprop", 2.0 * 2 ** 20 * 16 * 2 * 3, 4.0 * 2 ** 20 * 16 * 4))
    # bfs-like: pure traversal, ~0 flops, byte-dominated (graph16M)
    suite.append(("bfs", 16e6 * 2, 16e6 * 24.0))
    return suite


LINEAGE = ["K80", "P100", "V100", "A100", "GTX745", "GTX1050Ti", "RTX2060S",
           "TPUv4", "TPUv5e", "TPUv5p"]


def run(report):
    suite = _suite()
    report.section("Fig2: roofline-model kernel times across the lineage "
                   "(ms, fp32 peak basis)")
    times = {}
    for chip_name in LINEAGE:
        chip = hardware.get_chip(chip_name)
        for name, flops, nbytes in suite:
            t = balance.roofline_time(flops, nbytes, chip)
            times[(chip_name, name)] = t
            report.row("kernel_time", f"{chip_name}/{name}",
                       ms=round(t * 1e3, 3),
                       intensity=round(flops / nbytes, 2),
                       bound=("compute" if flops / (chip.tflops_f32 * 1e12)
                              > nbytes / (chip.mem_bw_gbs * 1e9)
                              else "memory"))

    report.section("Fig2-bottom: modelled generation-upgrade speedups "
                   "(geomean over the suite)")
    pairs = [("K80", "P100"), ("P100", "V100"), ("V100", "A100"),
             ("GTX745", "GTX1050Ti"), ("GTX1050Ti", "RTX2060S"),
             ("TPUv4", "TPUv5e"), ("TPUv5e", "TPUv5p")]
    for old, new in pairs:
        sp = [times[(old, k)] / times[(new, k)] for k, _, _ in suite]
        geo = math.exp(sum(math.log(s) for s in sp) / len(sp))
        report.row("upgrade", f"{old}->{new}", geomean_speedup=round(geo, 2),
                   min=round(min(sp), 2), max=round(max(sp), 2))
    report.note("paper comparison: measured K80->P100 ~3.95x (model: "
                "memory-bound kernels ~3.0x via BW ratio); V100->A100 "
                "measured 1.34x vs model >=1.38x — the model bounds from "
                "above exactly as the paper argues (toolchain/benchmark "
                "limitations explain the shortfall)")
