"""Autotuning benchmark: tuned-vs-default and tuned-vs-analytic-prediction.

This applies the paper's expectation-vs-measurement methodology to our own
autotuner: for each kernel we (a) report the empirical speedup of the tuned
config over the seed's hard-coded default, and (b) compare the analytic
roofline prediction against the measured ordering — how often does the
expectation model pick the right winner, and by how much is it off?

Runs the real Pallas kernels through the tuner (interpret mode on this CPU
host; pass --compiled on the tuning CLI for real-TPU numbers).  Uses a
fresh temp registry so the bench always re-measures.  The tuner times
candidates through ``repro.bench.timing`` — the same protocol as every
scenario row — and tunes the exact cells the ``smoke/*`` scenarios
measure, so a subsequent ``repro.bench.cli sweep`` resolves these winners
(``config_source: "tuned"``) when pointed at a persistent registry.
"""
import os
import tempfile

from repro.bench.scenario import get_scenario
from repro.tuning import Autotuner, Registry, default_task
from repro.tuning.autotuner import decode_config

KERNELS = ("stream", "matmul", "hotspot", "pathfinder")
SHAPES = {k: get_scenario(f"smoke/{k}").shape for k in KERNELS}


def run(report):
    report.section("autotune: tuned config vs hard-coded default "
                   "(empirical, Pallas interpret on this host)")
    registry = Registry(os.path.join(tempfile.mkdtemp(prefix="repro_tune_"),
                                     "registry.json"))
    tuner = Autotuner(registry, warmup=1, repeats=5)
    records = {}
    for kernel in KERNELS:
        task = default_task(kernel, shape=SHAPES[kernel])
        rec = tuner.tune(task)
        records[kernel] = rec
        best = decode_config(rec.best)
        report.row("autotune_speedup", kernel,
                   shape="x".join(map(str, rec.shape)),
                   default_us=round(rec.default_us, 1),
                   tuned_us=round(rec.best_us, 1),
                   speedup=round(rec.speedup_vs_default, 3),
                   best_strategy=best["strategy"].value,
                   best_config=";".join(
                       f"{k}={v}" for k, v in sorted(best.items())
                       if k != "strategy"),
                   candidates=rec.n_candidates, pruned=rec.n_pruned)
    report.note("speedup >= 1.0 by construction (the default is always "
                "measured under the same protocol); > 1.0 means the seed "
                "constant was not optimal for this backend")

    report.section("autotune: analytic expectation vs measurement "
                   "(the paper's Sec.6 methodology applied to ourselves)")
    for kernel, rec in records.items():
        ok = [m for m in rec.measurements if m.error is None
              and m.us_median > 0]
        if len(ok) < 2:
            continue
        # does the analytic model order candidate pairs correctly?
        agree = total = 0
        for i in range(len(ok)):
            for j in range(i + 1, len(ok)):
                a, b = ok[i], ok[j]
                if a.predicted_us == b.predicted_us:
                    continue
                total += 1
                if ((a.predicted_us < b.predicted_us)
                        == (a.us_median < b.us_median)):
                    agree += 1
        pred_best = min(ok, key=lambda m: m.predicted_us)
        meas_best = min(ok, key=lambda m: m.us_median)
        # how much faster is the measured winner than the predicted winner?
        regret = pred_best.us_median / meas_best.us_median \
            if meas_best.us_median else 0.0
        report.row("autotune_expectation", kernel,
                   pairwise_rank_agreement=round(agree / total, 3)
                   if total else 1.0,
                   predicted_winner_regret=round(regret, 3),
                   pred_best_us=round(pred_best.predicted_us, 1),
                   meas_best_us=round(meas_best.us_median, 1))
    report.note("rank agreement is the fraction of candidate pairs the "
                "roofline model orders like the measurements; regret is "
                "measured(pred winner)/measured(true winner) — the cost of "
                "trusting the model without measuring, i.e. exactly why the "
                "registry exists.  Interpret-mode timings reflect host "
                "emulation, not TPU DMA, so low agreement here is the "
                "paper's point: per-backend empirical tuning is unavoidable")
