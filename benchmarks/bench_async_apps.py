"""Paper Fig. 4: async-copy strategies applied to the four Rodinia kernels
(Hotspot, Pathfinder, NW, LUD).

The measured (kernel x strategy) grid is the ``fig4/*`` scenario set in
``repro.bench.scenario``, executed by ``repro.bench.runner`` (canonical
timing + ``kernels/ref.py`` oracle check per row); the per-kernel
tolerances live next to the scenarios in ``CHECK_TOL``.  The analytic
section reproduces the paper's finding that the winning pattern is
benchmark-dependent (Hotspot->Overlap, NW->Register Bypass,
Pathfinder->Drop-Off, LUD->size-dependent crossover).
"""
from repro.bench import runner, scenario
from repro.core.async_pipeline import Strategy


def run(report):
    report.section("Fig4: Rodinia kernels x async strategies — fig4/* "
                   "scenarios (Pallas interpret: correctness + host us/call)")
    opts = runner.RunOptions(warmup=1, repeats=3, emit=report.add_result)
    bench = runner.run_scenarios(scenario.scenarios(tag="fig4"), opts)
    failed = [r.scenario for r in bench.results
              if not r.metrics["check_ok"]]
    assert not failed, f"oracle check failed: {failed}"

    report.section("Fig4-model: TPU-target speedup over sync per kernel "
                   "(roofline overlap model at paper input sizes)")
    # (kernel, intensity flops/byte, tiles) — intensity decides the win
    cases = [("hotspot_8192", 10 / 12, 64), ("pathfinder_100k", 1.0, 128),
             ("nw_16384", 6 / 8, 128), ("lud_16384_inner", 64.0, 128),
             ("lud_8192_inner", 32.0, 64)]
    from .bench_async_micro import model_time
    for name, intensity, tiles in cases:
        nbytes = 256e6
        flops = intensity * nbytes
        t_sync = model_time(Strategy.SYNC, flops, nbytes, n_tiles=tiles)
        row = {}
        for s in Strategy:
            row[s.value] = round(
                t_sync / model_time(s, flops, nbytes, n_tiles=tiles), 3)
        best = max((v, k) for k, v in row.items())
        report.row("fig4_model", name, best=best[1], **row)
    report.note("memory-bound kernels (hotspot/nw/pathfinder) gain ~1.4-1.5x"
                " from overlap-family strategies; compute-bound LUD interior"
                " gains little — matching the paper's Fig 4 structure")
