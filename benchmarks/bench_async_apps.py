"""Paper Fig. 4: async-copy strategies applied to the four Rodinia kernels
(Hotspot, Pathfinder, NW, LUD).

Correctness + host-side us/call for every (kernel x strategy) via the actual
Pallas kernels (interpret mode), plus the TPU-target analytic speedups per
the same overlap model as Fig 3 — reproducing the paper's findings that the
winning pattern is benchmark-dependent (Hotspot->Overlap, NW->Register
Bypass, Pathfinder->Drop-Off, LUD->size-dependent crossover).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardware
from repro.core.async_pipeline import Strategy
from repro.kernels import ops


def _bench(fn, reps=1):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return out, (time.perf_counter() - t0) / reps * 1e6


def run(report):
    key = jax.random.PRNGKey(0)
    report.section("Fig4: Rodinia kernels x async strategies "
                   "(Pallas interpret: correctness + host us/call)")

    # hotspot (paper winner: Overlap 1.12-1.23x)
    k1, k2 = jax.random.split(key)
    temp = jax.random.uniform(k1, (32, 126), jnp.float32) * 100 + 300
    power = jax.random.uniform(k2, (32, 126), jnp.float32)
    from repro.kernels import ref
    want = ref.hotspot_ref(temp, power, iters=2)
    for s in Strategy:
        got, us = _bench(lambda: ops.hotspot(temp, power, iters=2,
                                             strategy=s, grid=1))
        err = float(jnp.abs(got - want).max())
        report.row("hotspot", s.value, us_per_call=round(us, 1),
                   max_err=err)
        assert err < 1e-2

    # pathfinder (paper winner: Drop-Off 1.04-1.11x)
    wall = jax.random.randint(key, (33, 128), 0, 10, jnp.int32)
    want = ref.pathfinder_ref(wall)
    for s in Strategy:
        got, us = _bench(lambda: ops.pathfinder(wall, strategy=s))
        ok = bool((np.asarray(got)[0] == np.asarray(want)).all())
        report.row("pathfinder", s.value, us_per_call=round(us, 1),
                   exact=ok)
        assert ok

    # nw (paper winner: Register Bypass 1.01-1.08x)
    scores = jax.random.randint(key, (32, 32), -3, 4).astype(jnp.float32)
    want = ref.nw_ref(scores, 10)
    for s in Strategy:
        got, us = _bench(lambda: ops.nw(scores, penalty=10, strategy=s))
        err = float(jnp.abs(got - want).max())
        report.row("nw", s.value, us_per_call=round(us, 1), max_err=err)
        assert err < 1e-3

    # lud (paper: size-dependent crossover RB <-> Overlap, 1.25-1.32x)
    a = jax.random.normal(key, (64, 64), jnp.float32) + 64 * jnp.eye(64)
    want = ref.lud_ref(a)
    for s in Strategy:
        got, us = _bench(lambda: ops.lud(a, bs=32, strategy=s))
        err = float(jnp.abs(got - want).max())
        report.row("lud", s.value, us_per_call=round(us, 1), max_err=err)
        assert err < 1e-2

    report.section("Fig4-model: TPU-target speedup over sync per kernel "
                   "(roofline overlap model at paper input sizes)")
    # (kernel, intensity flops/byte, tiles) — intensity decides the win
    cases = [("hotspot_8192", 10 / 12, 64), ("pathfinder_100k", 1.0, 128),
             ("nw_16384", 6 / 8, 128), ("lud_16384_inner", 64.0, 128),
             ("lud_8192_inner", 32.0, 64)]
    from .bench_async_micro import model_time
    for name, intensity, tiles in cases:
        nbytes = 256e6
        flops = intensity * nbytes
        t_sync = model_time(Strategy.SYNC, flops, nbytes, n_tiles=tiles)
        row = {}
        for s in Strategy:
            row[s.value] = round(
                t_sync / model_time(s, flops, nbytes, n_tiles=tiles), 3)
        best = max((v, k) for k, v in row.items())
        report.row("fig4_model", name, best=best[1], **row)
    report.note("memory-bound kernels (hotspot/nw/pathfinder) gain ~1.4-1.5x"
                " from overlap-family strategies; compute-bound LUD interior"
                " gains little — matching the paper's Fig 4 structure")
