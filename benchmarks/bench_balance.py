"""Paper Fig. 1: machine balance (B/F) and compute density across the GPU
lineage, extended with the TPU generations; §6 expected-speedup table.

Purely analytic (vendor peaks from ``core.hardware``) — nothing to time, so
this module stays a plain row emitter; measured rows belong to the
``repro.bench`` scenario runner."""
from repro.core import balance, hardware


def run(report):
    report.section("Fig1a: machine balance (B/F)")
    for name, chip in hardware.CATALOG.items():
        b = balance.machine_balance(chip)
        report.row("balance", name,
                   bf_f32=round(b.bf_f32, 4),
                   bf_f64=(round(b.bf_f64, 4) if chip.has_f64 else "n/a"),
                   bw_gbs=chip.mem_bw_gbs, tflops_f32=chip.tflops_f32)

    report.section("Fig1b: compute density (GFLOPS/mm^2)")
    for name, chip in hardware.CATALOG.items():
        if not chip.density_known:
            continue                     # die area unpublished: no density
        b = balance.machine_balance(chip)
        report.row("density", name,
                   density_f32=round(b.density_f32, 2),
                   density_f64=(round(b.density_f64, 2) if chip.has_f64
                                else "n/a"))

    report.section("S6: expected minimum upgrade speedups "
                   "T = min(FLOP ratio, BW ratio)")
    pairs = [("K80", "P100"), ("P100", "V100"), ("V100", "A100"),
             ("A100", "H100-SXM"), ("H100-SXM", "H200"),
             ("GTX1050Ti", "RTX2060S"), ("TPUv4", "TPUv5e"),
             ("TPUv5e", "TPUv5p")]
    for old, new in pairs:
        co, cn = hardware.get_chip(old), hardware.get_chip(new)
        report.row("speedup", f"{old}->{new}",
                   flop_ratio=round(cn.tflops_f32 / co.tflops_f32, 3),
                   bw_ratio=round(cn.mem_bw_gbs / co.mem_bw_gbs, 3),
                   t_speedup=round(balance.expected_speedup(co, cn), 3))
    # the paper's headline numbers, asserted (reproduction gate)
    v, a = hardware.get_chip("V100"), hardware.get_chip("A100")
    assert abs(balance.expected_speedup(v, a) - 1.38) < 0.01
    report.note("paper check: V100->A100 T_speedup = 1.38x reproduced; "
                "measured Rodinia average in the paper was 1.34x "
                "(under-delivery, the paper's central observation)")
