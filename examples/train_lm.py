"""End-to-end training driver: trains a reduced-config LM for a few hundred
steps with the full production substrate — grad accumulation, AdamW +
warmup-cosine, async checkpointing, preemption handling, straggler logging,
and exact resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 400 --resume  # continue

A ~100M-param preset exists for beefier hosts: --preset 100m (the default
preset is laptop-sized; this container has a single CPU core).
"""
import argparse
import logging
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.config import ArchConfig, AttnConfig, RunConfig
from repro.launch.train import train_loop

PRESETS = {
    # name: (layers, d_model, heads, kv, ff, vocab, batch, seq)
    "tiny": (4, 128, 4, 2, 512, 2048, 8, 128),        # ~2M params
    "20m": (8, 256, 8, 4, 1024, 8192, 8, 256),        # ~20M
    "100m": (12, 768, 12, 4, 3072, 32768, 8, 512),    # ~110M
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    L, d, h, kv, ff, vocab, batch, seq = PRESETS[args.preset]
    cfg = ArchConfig(name=f"lm-{args.preset}", family="dense", n_layers=L,
                     d_model=d, n_heads=h, n_kv_heads=kv, d_ff=ff,
                     vocab=vocab, attn=AttnConfig(chunk=128))
    run = RunConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps, microbatches=2, zero1=False)
    _, _, history = train_loop(cfg, run, steps=args.steps, batch=batch,
                               seq=seq, ckpt_dir=args.ckpt,
                               resume=args.resume)
    k = max(len(history) // 10, 1)
    print(f"ce: first-{k} avg {sum(history[:k])/k:.4f} -> "
          f"last-{k} avg {sum(history[-k:])/k:.4f} "
          f"({len(history)} steps this run)")


if __name__ == "__main__":
    main()
