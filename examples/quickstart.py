"""Quickstart: build a model from an assigned architecture config, run a
forward pass, take one training step, prefill + decode a few tokens, then
autotune a Pallas kernel and reuse the cached winner.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-1.5b]
"""
import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.core.config import RunConfig
from repro.data import synth_batch
from repro.distributed.sharding import split_tree
from repro.launch.train import build_train_step, set_param_axes
from repro.models import build_model
from repro.optim import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    args = ap.parse_args()

    # reduced config of the same family (full configs are dry-run only)
    cfg = get_smoke_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")

    model = build_model(cfg)
    params, axes = split_tree(model.init(jax.random.PRNGKey(0)))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params:,}")

    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, batch=2, seq=32, seed=0, step=0).items()}

    # forward
    logits = jax.jit(model.forward)(params, batch)
    print(f"forward logits: {logits.shape}")

    # one training step
    set_param_axes(axes)
    run = RunConfig(microbatches=2, zero1=False, warmup_steps=1,
                    total_steps=10)
    step_fn = jax.jit(build_train_step(model, run))
    params, opt, metrics = step_fn(params, adamw_init(params), batch,
                                   jnp.zeros((), jnp.int32))
    print(f"train step: ce={float(metrics['ce']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # prefill + decode 4 tokens greedily
    lg, state = jax.jit(lambda p, b: model.prefill(p, b, budget=40))(params,
                                                                     batch)
    toks = []
    for _ in range(4):
        t = jnp.argmax(lg[..., :cfg.vocab], axis=-1)[:, None]
        toks.append(t)
        lg, state = jax.jit(model.decode_step)(params, state,
                                               t.astype(jnp.int32))
    print("decoded:", jnp.concatenate(toks, 1).tolist())

    # --- Serving (continuous batching) --------------------------------------
    # ServingLoop serves a request queue with slot-level continuous
    # batching over a paged KV cache (src/repro/serve/README.md): a slot
    # is refilled the moment its request finishes instead of waiting for
    # the whole cohort, and every slot shares one block arena sized by a
    # global token budget.  Greedy outputs are bit-identical to solo
    # prefill+decode regardless of arrival order.  make_trace builds
    # deterministic uniform/poisson/bursty arrival traces.
    from repro.launch.serve import ServingLoop
    from repro.serve import make_trace

    loop = ServingLoop(cfg, params, batch=2, max_new=8, block_len=8)
    reqs = make_trace("poisson", 4, vocab=cfg.vocab, rate=0.5, seed=0,
                      prompt_lens=(5, 12), max_new=(4, 8))
    results = loop.run(reqs, max_steps=8)
    served = sum(len(v) for v in results.values())
    occ = loop.metrics.histogram("serve.batch_occupancy").snapshot()
    print(f"serve: [{loop.scheduler_kind}] {len(results)} requests / "
          f"{served} tokens, mean occupancy {occ['mean']:.2f}")
    # CLI equivalent:
    #   python -m repro.launch.serve --arrival poisson --requests 8 \
    #       --batch 4 --ragged --scheduler continuous --metrics-json m.json

    # Chunked prefill + copy-on-write prefix sharing: prefill runs in
    # chunk_tokens-sized pieces interleaved with decode (bounds TTFT under
    # long prompts), and prefix_cache=True content-addresses finished KV
    # blocks so requests sharing a prompt prefix (a system prompt, a
    # few-shot preamble) map it by reference instead of recomputing it.
    # Greedy outputs stay bit-identical to serving without sharing.
    shared = ServingLoop(cfg, params, batch=2, max_new=8, block_len=8,
                         chunk_tokens=16, prefix_cache=True)
    reqs = make_trace("poisson", 4, vocab=cfg.vocab, rate=0.5, seed=0,
                      prompt_lens=(5, 12), max_new=(4, 8),
                      prefix_len=16, prefix_group=2)
    shared.run(reqs, max_steps=8)
    hit = shared.scheduler.cache.cache_hit_ratio
    print(f"serve: prefix sharing cache-hit ratio {hit:.2f}")
    # CLI equivalent:
    #   python -m repro.launch.serve --arrival poisson --requests 8 \
    #       --prefix-len 16 --prefix-group 2 --block-len 8 --prefix-cache \
    #       --chunk-tokens 16 --metrics-json m.json

    # --- Autotuning ---------------------------------------------------------
    # The async-copy strategy / ring depth / tile shape of every Pallas
    # kernel are searched empirically (timed with the repo's one canonical
    # protocol, repro.bench.timing) and cached in a persistent registry
    # (schema-versioned JSON).  First call measures; every later run — and
    # serve.py / train.py at startup — reuses the cached winner.
    import tempfile
    from repro.kernels import ops
    from repro.tuning import Autotuner, Registry, default_task, tuned

    registry = Registry(os.path.join(tempfile.mkdtemp(), "registry.json"))
    task = default_task("stream", shape=(64, 128))
    rec = Autotuner(registry, repeats=2).tune(task)
    strat = rec.best["strategy"]
    print(f"autotune: stream best={strat} "
          f"{rec.best_us:.0f}us ({rec.speedup_vs_default:.2f}x vs default, "
          f"{rec.n_candidates} measured / {rec.n_pruned} pruned "
          f"analytically)")
    cfg = tuned("stream", (64, 128), registry=registry)   # cache hit
    x = jax.random.uniform(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    y = ops.stream(x, iters=4, **cfg)
    print(f"autotune: tuned stream call ok, out={y.shape}; registry at "
          f"{registry.path}")
    # CLI equivalent:  python -m repro.tuning.cli tune --kernel stream

    # --- Benchmarking (repro.bench) -----------------------------------------
    # Benchmarks are declarative: a Scenario names one (kernel x shape x
    # dtype x strategy) cell, the runner resolves the config (tuning
    # registry winner when one exists — config_source says which), checks
    # the kernel against its kernels/ref.py oracle, times it, and emits a
    # schema-v2 result row with full provenance.  `sweep` additionally
    # projects every scenario across the whole Chip lineage (the paper's
    # generation study).  See src/repro/bench/README.md to add a workload.
    from repro.bench import runner, scenarios

    sc = scenarios(only="smoke/stream")[0]
    res = runner.run_scenario(sc, runner.RunOptions(
        repeats=2, registry=registry))
    print(f"bench: {res.scenario} strategy={res.strategy} "
          f"config_source={res.config_source} "
          f"us_median={res.metrics['us_median']:.0f} "
          f"max_err={res.metrics['max_err']:.1e}")
    # CLI equivalents:
    #   python -m repro.bench.cli list                    # all scenarios
    #   python -m repro.bench.cli run --only fig3         # one figure
    #   python -m repro.bench.cli sweep --smoke --json BENCH_sweep.json

    # --- N-stage pipelines + the regime map ---------------------------------
    # Every kernel's async pipeline has a first-class shape: ring depth
    # (VMEM slots, not just double-buffering), wait_group (how many copies
    # may still be in flight when compute starts — the TPU analogue of
    # cp.async.wait_group N) and out_depth (write-back ring).  Pass them
    # per call, or as a PipelineSpec to the *_pallas entry points.
    y3 = ops.stream(x, iters=4, strategy="overlap", depth=3, wait_group=1)
    print(f"stream depth=3 wait_group=1 ok, out={y3.shape}")

    # Hopper-style TMA bulk copies are a strategy too: one descriptor per
    # tile, all operands completing on a shared per-slot mbarrier, always
    # the deepest issue-ahead (no wait_group axis).
    y4 = ops.stream(x, iters=4, strategy="tma", depth=3)
    print(f"stream strategy=tma depth=3 ok, out={y4.shape}")

    # The regime/* scenario family measures, per kernel, a sync baseline
    # plus async at ring depths 2/3/4; sweep() folds the measurements into
    # one "async pays / neutral / hurts" verdict row with the measured
    # break-even depth.
    regime_scs = scenarios(tag="regime", kernel="stream")
    report = runner.sweep(regime_scs, chips=["TPUv5e"], opts=runner.RunOptions(
        warmup=0, repeats=1, registry=registry))
    (verdict,) = [r for r in report.results if r.kind == "regime"]
    m = verdict.metrics
    be = m["break_even_depth"]
    print(f"regime: stream async {m['verdict']} "
          f"(break-even depth={be if be is not None else '-'}, "
          f"best=d{m['best_depth']}, {m['speedup']:.2f}x vs sync)")
    # CLI equivalent:
    #   python -m repro.bench.cli sweep --tag regime --json BENCH_regime.json

    # --- Lineage validation (repro.bench.lineage) ---------------------------
    # The paper's §6 expectation model, made predictive: catalog-derived
    # speedups for the K80 -> ... -> H100 arc, judged against committed
    # published numbers (experiments/baselines/LINEAGE_hopper.json).
    from repro.bench import lineage
    from repro.core import balance, hardware

    exp = balance.expect_speedup(hardware.get_chip("A100"),
                                 hardware.get_chip("H100-SXM"))
    verdicts = lineage.validate(lineage.load_reference(
        lineage.default_reference_path()))
    print(f"lineage: A100->H100-SXM expected {exp.expected:.2f}x "
          f"({exp.binds} bind); "
          f"{sum(v.ok for v in verdicts)}/{len(verdicts)} pairs within band")
    # CLI equivalent:  python -m repro.bench.cli lineage --json LINEAGE.json

    # --- Observability (repro.obs) ------------------------------------------
    # Tracing is off by default and free when off.  Enabled, every layer of
    # the measurement stack emits nested spans — sweep -> scenario ->
    # warmup/timed trials (and tune -> candidate in the autotuner) — which
    # export to JSONL or a Chrome trace that https://ui.perfetto.dev loads
    # directly.  The serving loop records TTFT / per-token latency /
    # occupancy into labeled metrics the same way.
    from repro.obs.trace import tracer
    from repro.obs.compare import compare_reports

    t = tracer()
    t.clear()
    t.enable()
    res2 = runner.run_scenario(sc, runner.RunOptions(
        repeats=2, registry=registry))
    t.disable()
    spans = t.spans()
    trace_path = os.path.join(tempfile.mkdtemp(), "trace.jsonl")
    t.save_jsonl(trace_path)
    print(f"obs: {len(spans)} spans "
          f"({', '.join(sorted({s.name for s in spans}))}); "
          f"row trace_id={res2.trace_id}; jsonl at {trace_path}")

    # the regression gate: diff two reports using each cell's own measured
    # spread (median +/- k*IQR of the baseline's kept trials), not a naive
    # percent threshold.  Identical runs gate clean.
    rep_a, rep_b = runner.new_report(), runner.new_report()
    rep_a.add(res)
    rep_b.add(res2)
    cmp_res = compare_reports(rep_a, rep_b)
    print(f"obs: gate {'REGRESSED' if cmp_res.n_regressions else 'ok'} "
          f"({cmp_res.counts()})")
    # CLI equivalents:
    #   python -m repro.bench.cli sweep --smoke --trace t.jsonl \
    #       --chrome-trace t.chrome.json
    #   python -m repro.obs.cli summary --trace t.jsonl
    #   python -m repro.obs.cli compare BENCH_base.json BENCH_new.json
    #   python -m repro.launch.serve --ragged --metrics-json m.json


if __name__ == "__main__":
    main()
