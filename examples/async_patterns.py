"""The paper's three async-copy patterns, demonstrated on the actual Pallas
kernels (interpret mode) with the TPU-target speedup model alongside —
a runnable version of paper Fig 3/4.

    PYTHONPATH=src python examples/async_patterns.py
"""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.async_pipeline import Strategy
from repro.core.hardware import PEAK_FLOPS, HBM_BW
from repro.kernels import ops
from repro.kernels.stream import stream_flops_bytes


def main():
    print(__doc__)
    x = jax.random.uniform(jax.random.PRNGKey(0), (512, 256), jnp.float32)

    print(f"{'strategy':>16s} {'iters':>6s} {'host us':>9s} "
          f"{'TPU model':>10s}  (speedup over sync)")
    from benchmarks.bench_async_micro import model_time
    # the TPU-model column is evaluated at a production tile-stream size
    # (16 MiB working set); the host column times the small demo kernel
    for iters in (1, 16, 256):
        flops, nbytes = stream_flops_bytes((1 << 14, 256), iters)
        t_sync = model_time(Strategy.SYNC, flops, nbytes)
        for s in Strategy:
            fn = lambda: ops.stream(x, iters=iters, strategy=s,
                                    tile_rows=16, n_tiles=8)
            jax.block_until_ready(fn())
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            us = (time.perf_counter() - t0) * 1e6
            model = t_sync / model_time(s, flops, nbytes)
            print(f"{s.value:>16s} {iters:>6d} {us:>9.0f} {model:>9.2f}x")
        print()
    print("paper's conclusion, reproduced: overlap/drop-off win while the "
          "kernel is memory-bound (low iters); at high arithmetic intensity "
          "the async machinery is pure overhead.")


if __name__ == "__main__":
    main()
