"""Batched serving example: continuous batching over a paged KV cache
(src/repro/serve/README.md) for a request queue — slots refill as requests
finish; families without paged decode fall back to lockstep cohorts (the
decode_32k / long_500k dry-run cells lower exactly that step function).

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b --requests 6
"""
import argparse
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.distributed.sharding import split_tree
from repro.launch.serve import Request, ServingLoop
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=args.batch, max_new=args.max_new)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, (args.prompt_len,),
                                        dtype=np.int64).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    results = loop.run(reqs, temperature=args.temperature)
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"{cfg.name}: [{loop.scheduler_kind}] served {len(results)} "
          f"requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on this host)")
    for uid in sorted(results):
        print(f"  req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
