"""repro.obs subsystem tests: span tracing (nesting, retroactive record,
disabled no-op, JSONL/Chrome export), labeled metrics, the noise-aware
regression gate, and the obs CLI's exit-code contract."""
import json
import time

import pytest

from repro.bench.results import BenchReport, BenchResult
from repro.obs import compare as cmp_mod
from repro.obs import metrics as metrics_mod
from repro.obs.cli import main as obs_cli_main
from repro.obs.compare import (CompareResult, cell_noise_us, compare_reports,
                               format_compare)
from repro.obs.metrics import Registry, quantile
from repro.obs.trace import Span, Tracer, chrome_trace, load_jsonl


# --- tracing ----------------------------------------------------------------

def test_span_nesting_and_attrs():
    t = Tracer(enabled=True)
    with t.span("outer", kind="scenario") as outer:
        with t.span("inner") as inner:
            assert inner.parent_id == outer.span_id
        outer.attrs["us_median"] = 42.0     # mutable until export
    spans = t.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    assert all(s.trace_id == t.trace_id for s in spans)
    got_outer = next(s for s in spans if s.name == "outer")
    assert got_outer.attrs == {"kind": "scenario", "us_median": 42.0}
    assert got_outer.parent_id is None
    assert all(s.dur_us >= 0 for s in spans)


def test_disabled_tracer_records_nothing():
    t = Tracer()                            # disabled by default
    with t.span("nope", x=1) as sp:
        assert sp is None
    assert t.record("nope", 0.0, 1.0) is None
    assert t.spans() == []
    # the disabled span() must return one shared object, not allocate
    assert t.span("a") is t.span("b")


def test_record_is_retroactive_and_nests():
    t = Tracer(enabled=True)
    t0 = time.perf_counter()
    t1 = t0 + 0.001
    with t.span("scenario") as outer:
        sp = t.record("timed", t0, t1, trial=0, outlier=False)
    assert sp.parent_id == outer.span_id
    assert sp.dur_us == pytest.approx(1000.0)
    assert sp.attrs == {"trial": 0, "outlier": False}


def test_span_exception_annotates_and_closes():
    t = Tracer(enabled=True)
    with pytest.raises(ValueError):
        with t.span("boom"):
            raise ValueError("x")
    (sp,) = t.spans()
    assert sp.attrs["error"] == "ValueError"
    assert sp.t1_us is not None


def test_jsonl_round_trip(tmp_path):
    t = Tracer(enabled=True)
    with t.span("a", n=3):
        t.record("b", 1.0, 2.0)
    path = str(tmp_path / "t.jsonl")
    assert t.save_jsonl(path) == 2
    got = load_jsonl(path)
    by_name = {s.name: s for s in got}
    assert by_name["b"].parent_id == by_name["a"].span_id
    assert by_name["a"].attrs == {"n": 3}
    assert by_name["b"].t0_us == 1e6 and by_name["b"].t1_us == 2e6


def test_chrome_trace_events():
    t = Tracer(enabled=True)
    with t.span("outer"):
        t.record("early", 0.5, 0.6)        # earlier ts than outer
    doc = chrome_trace(t.spans())
    ev = doc["traceEvents"]
    assert [e["ts"] for e in ev] == sorted(e["ts"] for e in ev)
    for e in ev:
        assert e["ph"] == "X" and e["dur"] >= 0
        assert {"name", "ts", "pid", "tid", "args"} <= set(e)
        assert "span_id" in e["args"]
    assert doc["displayTimeUnit"] == "ms"
    # open spans are dropped, not exported half-finished
    open_span = Span(name="open", t0_us=0.0)
    assert chrome_trace([open_span])["traceEvents"] == []


def test_tracer_clear_resets_trace_id():
    t = Tracer(enabled=True)
    with t.span("a"):
        pass
    old = t.trace_id
    t.clear()
    assert t.spans() == [] and t.trace_id != old


# --- metrics ----------------------------------------------------------------

def test_counter_gauge_basics():
    r = Registry()
    c = r.counter("reqs")
    c.inc()
    c.inc(2)
    assert c.value == 3
    g = r.gauge("depth")
    g.set(5)
    g.inc(-2)
    assert g.value == 3
    # same (name, labels) -> same instance; different labels -> distinct
    assert r.counter("reqs") is c
    assert r.counter("reqs", arch="a") is not c


def test_histogram_quantiles_and_ring():
    r = Registry()
    h = r.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["sum"] == 5050.0
    assert snap["min"] == 1.0 and snap["max"] == 100.0
    assert snap["p50"] == pytest.approx(50.5)
    assert snap["p99"] == pytest.approx(99.01)
    # ring: quantiles describe the recent window, totals stay exact
    small = metrics_mod.Histogram("w", (), max_samples=4)
    for v in [1.0, 2.0, 3.0, 4.0, 100.0, 100.0]:
        small.observe(v)
    snap = small.snapshot()
    assert snap["count"] == 6 and snap["sum"] == 210.0
    assert snap["p50"] >= 3.5                  # 1.0/2.0 were overwritten


def test_registry_snapshot_and_save(tmp_path):
    r = Registry()
    r.counter("b").inc()
    r.histogram("a", arch="x").observe(1.0)
    rows = r.snapshot()
    assert [row["name"] for row in rows] == ["a", "b"]   # sorted
    assert rows[0]["labels"] == {"arch": "x"}
    path = str(tmp_path / "m.json")
    r.save(path)
    doc = json.load(open(path))
    assert doc["kind"] == "obs-metrics" and len(doc["rows"]) == 2


def test_quantile_edges():
    assert quantile([], 0.5) == 0.0
    assert quantile([7.0], 0.99) == 7.0
    assert quantile([1.0, 3.0], 0.5) == 2.0
    assert quantile([1.0, 2.0, 3.0, 4.0], 1.0) == 4.0


# --- regression gate --------------------------------------------------------

def _row(scenario, us_median, times=None, chip="TPUv5e", kind="measured",
         **kw):
    metrics = {"us_median": us_median}
    if times is not None:
        metrics["times_us"] = times
        metrics["us_std"] = 0.0
    base = dict(scenario=scenario, kernel="stream", shape=[256, 256],
                dtype="float32", strategy="overlap", chip=chip,
                metrics=metrics, kind=kind, interpret=True)
    base.update(kw)
    return BenchResult(**base)


def _report(*rows):
    r = BenchReport(jax_version="0", backend="cpu")
    r.extend(rows)
    return r


TIGHT = [100.0, 100.5, 101.0, 101.5, 102.0]     # IQR = 1.0


def test_identical_reports_all_pass():
    rep = _report(_row("a", 101.0, TIGHT), _row("b", 50.0, [50.0] * 5))
    res = compare_reports(rep, rep)
    assert res.n_regressions == 0
    assert res.counts() == {"pass": 2, "regress": 0, "improve": 0,
                            "new": 0, "missing": 0}


def test_regress_and_improve_beyond_noise_band():
    base = _report(_row("a", 101.0, TIGHT))
    slow = _report(_row("a", 130.0, [130.0] * 5))   # >> 3*IQR and >5%
    fast = _report(_row("a", 80.0, [80.0] * 5))
    assert compare_reports(base, slow).verdicts[0].verdict == "regress"
    assert compare_reports(base, slow).n_regressions == 1
    assert compare_reports(base, fast).verdicts[0].verdict == "improve"


def test_band_scales_with_baseline_noise():
    """The same absolute delta passes on a noisy cell and flags on a
    quiet one — the whole point of a noise-aware gate."""
    noisy = [80.0, 95.0, 105.0, 120.0, 130.0]       # IQR = 25
    new = _report(_row("a", 130.0, [130.0] * 5))
    assert compare_reports(_report(_row("a", 101.0, noisy)),
                           new).verdicts[0].verdict == "pass"
    assert compare_reports(_report(_row("a", 101.0, TIGHT)),
                           new).verdicts[0].verdict == "regress"


def test_candidate_noise_cannot_widen_the_gate():
    """A regression that also inflates its own variance must still flag:
    the band comes from the BASELINE's spread only."""
    base = _report(_row("a", 101.0, TIGHT))
    slow_noisy = _report(_row("a", 1010.0, [t * 10 for t in TIGHT]))
    assert compare_reports(base, slow_noisy).verdicts[0].verdict == "regress"


def test_rel_floor_absorbs_zero_iqr_jitter():
    base = _report(_row("a", 100.0, [100.0] * 5))    # zero spread
    within = _report(_row("a", 104.0, [104.0] * 5))  # +4% < 5% floor
    beyond = _report(_row("a", 106.0, [106.0] * 5))
    assert compare_reports(base, within).verdicts[0].verdict == "pass"
    assert compare_reports(base, beyond).verdicts[0].verdict == "regress"


def test_normalize_absorbs_uniform_host_speed():
    """A uniformly 2x slower host is machine lottery, not a regression —
    but a cell that moved relative to its own sweep still flags."""
    base = _report(_row("a", 100.0, [100.0] * 5),
                   _row("b", 200.0, [200.0] * 5),
                   _row("c", 300.0, [300.0] * 5))
    uniform = _report(_row("a", 200.0, [200.0] * 5),
                      _row("b", 400.0, [400.0] * 5),
                      _row("c", 600.0, [600.0] * 5))
    res = compare_reports(base, uniform, normalize=True)
    assert res.host_scale == pytest.approx(2.0)
    assert res.n_regressions == 0
    # same host scale, but cell "c" regressed 3x on top of it
    mixed = _report(_row("a", 200.0, [200.0] * 5),
                    _row("b", 400.0, [400.0] * 5),
                    _row("c", 1800.0, [1800.0] * 5))
    res = compare_reports(base, mixed, normalize=True)
    bad = [v for v in res.verdicts if v.verdict == "regress"]
    assert [v.scenario for v in bad] == ["c"]
    # without normalization all three cells flag
    assert compare_reports(base, mixed).n_regressions == 3


def test_missing_new_and_model_rows():
    base = _report(_row("a", 100.0, TIGHT), _row("gone", 50.0, [50.0] * 5),
                   _row("proj", 1.0, kind="model"))
    new = _report(_row("a", 100.5, TIGHT), _row("added", 70.0, [70.0] * 5),
                  _row("proj", 99.0, kind="model"))
    res = compare_reports(base, new)
    got = {v.scenario: v.verdict for v in res.verdicts}
    # model rows are roofline predictions, never gated
    assert got == {"a": "pass", "gone": "missing", "added": "new"}
    assert res.n_regressions == 0               # missing/new do not gate


def test_cell_noise_falls_back_to_std():
    assert cell_noise_us({"times_us": TIGHT}) == pytest.approx(1.0)
    # < 4 samples or no samples: derived from the std instead
    assert cell_noise_us({"times_us": [1.0, 2.0], "us_std": 2.0}) == \
        pytest.approx(cmp_mod._STD_TO_IQR * 2.0)
    assert cell_noise_us({"us_std": 0.0}) == 0.0
    assert cell_noise_us({}) == 0.0


def test_compare_result_round_trip(tmp_path):
    res = compare_reports(_report(_row("a", 101.0, TIGHT)),
                          _report(_row("a", 130.0, [130.0] * 5)))
    path = str(tmp_path / "CMP.json")
    res.save(path)
    got = CompareResult.load(path)
    assert got.counts() == res.counts()
    assert got.verdicts[0].verdict == "regress"
    assert got.verdicts[0].delta_pct == pytest.approx(
        res.verdicts[0].delta_pct)
    with pytest.raises(ValueError):
        CompareResult.from_dict({"kind": "not-a-compare"})


def test_format_compare_mentions_gate_and_regressions():
    res = compare_reports(_report(_row("a", 101.0, TIGHT)),
                          _report(_row("a", 130.0, [130.0] * 5)))
    text = format_compare(res, base_path="B.json", new_path="N.json")
    assert "GATE: REGRESSED" in text and "regress" in text
    ok = compare_reports(_report(_row("a", 101.0, TIGHT)),
                         _report(_row("a", 101.0, TIGHT)))
    assert "GATE: ok" in format_compare(ok)


# --- CLI --------------------------------------------------------------------

def _save_report(tmp_path, name, *rows):
    path = str(tmp_path / name)
    _report(*rows).save(path)
    return path


def test_cli_compare_exit_codes(tmp_path, capsys):
    base = _save_report(tmp_path, "B.json", _row("a", 101.0, TIGHT))
    same = _save_report(tmp_path, "S.json", _row("a", 101.2, TIGHT))
    slow = _save_report(tmp_path, "R.json", _row("a", 130.0, [130.0] * 5))
    assert obs_cli_main(["compare", base, same]) == 0
    assert "GATE: ok" in capsys.readouterr().out
    out_json = str(tmp_path / "CMP.json")
    assert obs_cli_main(["compare", base, slow, "--json", out_json]) == 1
    assert "GATE: REGRESSED" in capsys.readouterr().out
    assert json.load(open(out_json))["counts"]["regress"] == 1
    # gate knobs pass through: a huge rel-floor waives the regression
    assert obs_cli_main(["compare", base, slow, "--rel-floor", "0.5"]) == 0
    capsys.readouterr()


def test_cli_summary_and_export_trace(tmp_path, capsys):
    t = Tracer(enabled=True)
    with t.span("scenario:x", kernel="stream"):
        t.record("timed", 1.0, 1.001, trial=0)
    jsonl = str(tmp_path / "t.jsonl")
    t.save_jsonl(jsonl)
    r = Registry()
    r.histogram("serve.ttft_ms").observe(12.0)
    mpath = str(tmp_path / "m.json")
    r.save(mpath)

    assert obs_cli_main(["summary", "--trace", jsonl,
                         "--metrics", mpath]) == 0
    out = capsys.readouterr().out
    assert "scenario:x" in out and "serve.ttft_ms" in out

    chrome = str(tmp_path / "t.chrome.json")
    assert obs_cli_main(["export-trace", jsonl, chrome]) == 0
    capsys.readouterr()
    doc = json.load(open(chrome))
    assert len(doc["traceEvents"]) == 2
    assert {e["name"] for e in doc["traceEvents"]} == {"scenario:x", "timed"}


def test_cli_summary_requires_an_input():
    with pytest.raises(SystemExit):
        obs_cli_main(["summary"])


def test_obs_package_imports_stay_acyclic():
    """bench.timing imports obs.trace, so importing the obs package alone
    must never pull in repro.bench (the compare module is lazy)."""
    import subprocess
    import sys
    code = ("import sys; import repro.obs; "
            "bad = [m for m in sys.modules if m.startswith('repro.bench')]; "
            "sys.exit(1 if bad else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                          cwd=str(__import__("pathlib").Path(
                              __file__).resolve().parent.parent))
    assert proc.returncode == 0, \
        "importing repro.obs eagerly imported repro.bench.*"
