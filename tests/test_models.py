"""Model-stack correctness: chunked-vs-stepwise recurrence equivalence,
chunked attention vs the naive oracle, MoE routing invariants, and the
end-to-end decode == teacher-forced-forward consistency check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ArchConfig, AttnConfig, MoEConfig, SSMConfig
from repro.distributed.sharding import split_tree
from repro.kernels import ref
from repro.models import build_model
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm


def key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# chunked attention == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 16)])
@pytest.mark.parametrize("h,kvh", [(4, 2), (6, 2), (4, 4)])
def test_attend_chunked_vs_oracle(causal, window, h, kvh):
    b, s, d = 2, 64, 16
    ks = jax.random.split(key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    idx = attn.kv_index_map(h, kvh, h)
    got = attn.attend_chunked(q, k, v, idx, causal=causal, window=window,
                              chunk=16)
    for bi in range(b):
        qh = q[bi].transpose(1, 0, 2)
        kh = jnp.repeat(k[bi].transpose(1, 0, 2), h // kvh, axis=0)
        vh = jnp.repeat(v[bi].transpose(1, 0, 2), h // kvh, axis=0)
        want = ref.attention_ref(qh, kh, vh, causal=causal, window=window)
        np.testing.assert_allclose(got[bi].transpose(1, 0, 2), want,
                                   rtol=2e-5, atol=2e-5)


def test_attend_chunked_head_padding_exact():
    """Padded q heads must not change the real heads' outputs."""
    b, s, d, h, kvh = 1, 32, 8, 3, 1
    ks = jax.random.split(key(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    base = attn.attend_chunked(q, k, v, attn.kv_index_map(h, kvh, h),
                               causal=True, window=0, chunk=8)
    q_pad = jnp.concatenate([q, jnp.zeros((b, s, 2, d))], axis=2)
    padded = attn.attend_chunked(q_pad, k, v, attn.kv_index_map(h, kvh, h + 2),
                                 causal=True, window=0, chunk=8)
    np.testing.assert_allclose(padded[:, :, :h], base, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# recurrent blocks: chunkwise == stepwise
# ---------------------------------------------------------------------------

def test_mlstm_chunkwise_equals_stepwise():
    B, S, H, dh = 2, 32, 2, 8
    ks = jax.random.split(key(2), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    i_raw = jax.random.normal(ks[3], (B, S, H))
    f_raw = jax.random.normal(ks[4], (B, S, H)) + 2.0
    st0 = ssm.mlstm_state_init(B, H, dh)
    h_chunk, st_c = ssm.mlstm_seq(q, k, v, i_raw, f_raw, st0, chunk=8)
    st = st0
    outs = []
    for t in range(S):
        h, st = ssm.mlstm_step(q[:, t], k[:, t], v[:, t], i_raw[:, t],
                               f_raw[:, t], st)
        outs.append(h)
    np.testing.assert_allclose(h_chunk, jnp.stack(outs, 1), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(st_c.c, st.c, rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_size_invariance():
    B, S, H, dh = 1, 24, 2, 4
    ks = jax.random.split(key(3), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    i_raw = jax.random.normal(ks[3], (B, S, H))
    f_raw = jax.random.normal(ks[4], (B, S, H)) + 1.0
    st0 = ssm.mlstm_state_init(B, H, dh)
    h1, _ = ssm.mlstm_seq(q, k, v, i_raw, f_raw, st0, chunk=4)
    h2, _ = ssm.mlstm_seq(q, k, v, i_raw, f_raw, st0, chunk=12)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)


def test_mamba_chunkwise_equals_stepwise():
    cfg = ArchConfig(name="t", family="hybrid", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=1, d_ff=32, vocab=64,
                     ssm=SSMConfig(kind="mamba", d_state=4, chunk=8))
    p, _ = split_tree(ssm.mamba_init(key(4), cfg, d_inner=32))
    B, S = 2, 32
    x = jax.random.normal(key(5), (B, S, 16))
    st0 = ssm.mamba_state_init(B, 32, 4)
    y_seq, st_seq = ssm.mamba_apply(p, x, cfg, st0, mode="train",
                                    compute_dtype=jnp.float32)
    ys, st = [], st0
    for t in range(S):
        y, st = ssm.mamba_apply(p, x[:, t:t + 1], cfg, st, mode="decode",
                                compute_dtype=jnp.float32)
        ys.append(y)
    np.testing.assert_allclose(y_seq, jnp.concatenate(ys, 1), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(st_seq.s, st.s, rtol=2e-3, atol=2e-3)


def test_slstm_seq_equals_stepwise():
    cfg = ArchConfig(name="t", family="ssm", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=0, vocab=64)
    p, _ = split_tree(ssm.slstm_init(key(6), cfg, n_heads=2))
    B, S = 2, 16
    x = jax.random.normal(key(7), (B, S, 16))
    st0 = ssm.slstm_state_init(B, 2, 8)
    out, _ = ssm.slstm_block(p, x, cfg, st0, mode="train", n_heads=2,
                             compute_dtype=jnp.float32)
    outs, st = [], st0
    for t in range(S):
        o, st = ssm.slstm_block(p, x[:, t:t + 1], cfg, st, mode="decode",
                                n_heads=2, compute_dtype=jnp.float32)
        outs.append(o)
    np.testing.assert_allclose(out, jnp.concatenate(outs, 1), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

def _moe_cfg(**kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                n_kv_heads=2, d_ff=0, vocab=64,
                moe=MoEConfig(n_experts=8, top_k=2, n_shared=0,
                              d_ff_expert=16, capacity_factor=2.0))
    base.update(kw)
    return ArchConfig(**base)


def test_moe_output_finite_and_grad():
    cfg = _moe_cfg()
    p, _ = split_tree(moe_mod.moe_init(key(8), cfg))
    x = jax.random.normal(key(9), (2, 16, 32))
    out, aux = moe_mod.moe_apply(p, x, cfg, compute_dtype=jnp.float32)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))
    g = jax.grad(lambda pp: moe_mod.moe_apply(pp, x, cfg,
                                              jnp.float32)[0].sum())(p)
    assert sum(float(jnp.abs(t).sum()) for t in jax.tree.leaves(g)) > 0


def test_moe_aux_loss_balanced_router_is_one():
    """With perfectly uniform routing the Switch aux loss equals 1."""
    cfg = _moe_cfg()
    p, _ = split_tree(moe_mod.moe_init(key(10), cfg))
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])  # uniform probs
    x = jax.random.normal(key(11), (4, 16, 32))
    _, aux = moe_mod.moe_apply(p, x, cfg, compute_dtype=jnp.float32)
    assert abs(float(aux) - 1.0) < 0.05


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> tiny, most tokens are dropped: output norm
    shrinks but stays finite."""
    cfg_big = _moe_cfg()
    cfg_small = _moe_cfg(moe=MoEConfig(n_experts=8, top_k=2, n_shared=0,
                                       d_ff_expert=16, capacity_factor=0.1))
    p, _ = split_tree(moe_mod.moe_init(key(12), cfg_big))
    x = jax.random.normal(key(13), (2, 64, 32))
    out_big, _ = moe_mod.moe_apply(p, x, cfg_big, compute_dtype=jnp.float32)
    out_small, _ = moe_mod.moe_apply(p, x, cfg_small,
                                     compute_dtype=jnp.float32)
    assert float(jnp.linalg.norm(out_small)) < float(jnp.linalg.norm(out_big))
    assert bool(jnp.isfinite(out_small).all())


# ---------------------------------------------------------------------------
# decode == teacher-forced forward (end-to-end, per family)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family_kw", [
    dict(family="dense"),
    dict(family="hybrid", ssm=SSMConfig(kind="mamba", d_state=4, chunk=8),
         attn=AttnConfig(kind="sliding", window=8, chunk=8)),
    dict(family="ssm", d_ff=0, n_kv_heads=4,
         attn=AttnConfig(kind="none"),
         ssm=SSMConfig(kind="xlstm", slstm_every=2, chunk=8)),
], ids=["dense", "hybrid", "ssm"])
def test_decode_matches_forward(family_kw):
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=128, attn=AttnConfig(chunk=8))
    base.update(family_kw)
    cfg = ArchConfig(**base)
    model = build_model(cfg)
    params, _ = split_tree(model.init(key(14)))
    B, S, EXTRA = 2, 16, 4
    toks = jax.random.randint(key(15), (B, S + EXTRA), 0, cfg.vocab)
    # teacher-forced forward over the full sequence
    full = model.forward(params, {"tokens": toks,
                                  "labels": jnp.zeros_like(toks)})
    # prefill on the prefix, decode the rest one token at a time.
    # tolerance: the model path is bf16 (matmuls at input dtype with fp32
    # accumulation), and decode/chunked paths sum in different orders
    logits, state = model.prefill(params, {"tokens": toks[:, :S]},
                                  budget=S + EXTRA)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, S - 1]), rtol=6e-2,
                               atol=6e-2)
    for t in range(EXTRA):
        logits, state = model.decode_step(params, state, toks[:, S + t:S + t + 1])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, S + t]), rtol=6e-2,
                                   atol=6e-2)
