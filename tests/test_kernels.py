"""Per-kernel correctness: every Pallas kernel, swept over shapes/dtypes and
strategies, asserted allclose against the pure-jnp oracle in kernels/ref.py
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Strategy
from repro.kernels import ops, ref

STRATEGIES = list(Strategy)


def key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# stream (paper §4.1 microbenchmark)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("shape,tile_rows,n_tiles", [
    ((64, 128), 8, 4),
    ((128, 256), 16, 4),
    ((96, 128), 8, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream(strategy, shape, tile_rows, n_tiles, dtype):
    if shape[0] % (tile_rows * n_tiles):
        pytest.skip("shape not divisible")
    x = jax.random.uniform(key(0), shape, jnp.float32).astype(dtype)
    got = ops.stream(x, iters=3, strategy=strategy, tile_rows=tile_rows,
                     n_tiles=n_tiles)
    want = ref.stream_ref(x.astype(jnp.float32), 3)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("depth", [2, 3, 4])
def test_stream_depths(depth):
    x = jax.random.uniform(key(1), (64, 128), jnp.float32)
    got = ops.stream(x, iters=2, strategy=Strategy.OVERLAP, depth=depth)
    np.testing.assert_allclose(got, ref.stream_ref(x, 2), rtol=1e-6)


def test_stream_zero_iters():
    x = jax.random.uniform(key(2), (32, 128), jnp.float32)
    got = ops.stream(x, iters=0)
    np.testing.assert_allclose(got, x, rtol=1e-7)


# ---------------------------------------------------------------------------
# hotspot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("shape,grid", [((64, 126), 2), ((32, 128), 1)])
def test_hotspot(strategy, shape, grid):
    k1, k2 = jax.random.split(key(3))
    temp = jax.random.uniform(k1, shape, jnp.float32) * 100 + 300
    power = jax.random.uniform(k2, shape, jnp.float32)
    got = ops.hotspot(temp, power, iters=2, strategy=strategy, grid=grid)
    want = ref.hotspot_ref(temp, power, iters=2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# pathfinder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("rows,cols", [(33, 128), (17, 256)])
def test_pathfinder(strategy, rows, cols):
    wall = jax.random.randint(key(4), (rows, cols), 0, 10, jnp.int32)
    got = ops.pathfinder(wall, strategy=strategy)
    want = ref.pathfinder_ref(wall)
    np.testing.assert_array_equal(np.asarray(got)[0], want)


# ---------------------------------------------------------------------------
# needleman-wunsch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("n,penalty", [(32, 10), (64, 3)])
def test_nw(strategy, n, penalty):
    scores = jax.random.randint(key(5), (n, n), -3, 4).astype(jnp.float32)
    got = ops.nw(scores, penalty=penalty, strategy=strategy)
    want = ref.nw_ref(scores, penalty)
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------------
# LUD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("n,bs", [(64, 32), (128, 32)])
def test_lud(strategy, n, bs):
    a = jax.random.normal(key(6), (n, n), jnp.float32) + n * jnp.eye(n)
    got = np.asarray(ops.lud(a, bs=bs, strategy=strategy))
    want = ref.lud_ref(a)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # reconstruction: L @ U == A
    L = np.tril(got, -1) + np.eye(n)
    U = np.triu(got)
    np.testing.assert_allclose(L @ U, np.asarray(a), rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(strategy, m, k, n, dtype):
    a = jax.random.normal(key(7), (m, k)).astype(dtype)
    b = jax.random.normal(key(8), (k, n)).astype(dtype)
    got = ops.matmul(a, b, strategy=strategy, depth=3)
    want = ref.matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy",
                         [Strategy.OVERLAP, Strategy.SYNC, Strategy.DROP_OFF])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 256)])
@pytest.mark.parametrize("h,kvh", [(4, 2), (4, 4), (8, 1)])
def test_flash_attention(strategy, causal, window, h, kvh):
    s, d = 256, 64
    ks = jax.random.split(key(9), 3)
    q = jax.random.normal(ks[0], (h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (kvh, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (kvh, s, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              strategy=strategy, bq=128, bk=128)
    kr = jnp.repeat(k, h // kvh, axis=0)
    vr = jnp.repeat(v, h // kvh, axis=0)
    want = ref.attention_ref(q, kr, vr, causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_batched():
    b, h, s, d = 2, 4, 256, 64
    ks = jax.random.split(key(10), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, 2, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, 2, s, d), jnp.float32)
    got = ops.flash_attention(q, k, v)
    for i in range(b):
        want = ref.attention_ref(q[i], jnp.repeat(k[i], 2, 0),
                                 jnp.repeat(v[i], 2, 0))
        np.testing.assert_allclose(got[i], want, rtol=2e-5, atol=2e-5)
