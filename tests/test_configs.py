"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.core.config import RunConfig
from repro.distributed.sharding import split_tree
from repro.launch.train import build_train_step, set_param_axes
from repro.models import build_model
from repro.optim import adamw_init

B, S = 2, 32


def make_batch(cfg, b=B, s=S, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    n_text = s - (cfg.n_patches or 0)
    if cfg.is_encdec:
        n_text = s // 2
    batch = {
        "tokens": jax.random.randint(ks[0], (b, n_text), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, n_text), 0, cfg.vocab),
    }
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            ks[2], (b, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            ks[2], (b, s - n_text, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params_ann = model.init(jax.random.PRNGKey(0))
    params, axes = split_tree(params_ann)
    batch = make_batch(cfg)

    # forward: logits shape + finite
    logits = jax.jit(model.forward)(params, batch)
    n_pos = batch["tokens"].shape[1] + (cfg.n_patches or 0)
    assert logits.shape[0] == B and logits.shape[1] == n_pos
    assert logits.shape[2] >= cfg.vocab
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all()), arch

    # one full train step (grads + adamw update): params change, no NaNs
    set_param_axes(axes)
    run = RunConfig(microbatches=2, zero1=False, total_steps=10,
                    warmup_steps=2)
    step = jax.jit(build_train_step(model, run))
    opt = adamw_init(params)
    new_params, new_opt, metrics = step(params, opt, batch,
                                        jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(metrics["ce"])), arch
    assert float(metrics["ce"]) > 0
    deltas = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                          params, new_params)
    assert max(jax.tree.leaves(deltas)) > 0, "params did not move"
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(cfg)
    logits, state = jax.jit(model.prefill)(params, batch)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all()), arch
    toks = jnp.argmax(logits[..., :cfg.vocab], axis=-1)[:, None]
    logits2, state2 = jax.jit(model.decode_step)(params, state,
                                                 toks.astype(jnp.int32))
    assert logits2.shape[0] == B
    assert bool(jnp.isfinite(logits2[..., :cfg.vocab]).all()), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expected = {
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, (arch, got, expected)


def test_moe_configs():
    q3 = get_config("qwen3-moe-235b-a22b")
    assert (q3.moe.n_experts, q3.moe.top_k, q3.moe.n_shared,
            q3.moe.d_ff_expert) == (128, 8, 0, 1536)
    q2 = get_config("qwen2-moe-a2.7b")
    assert (q2.moe.n_experts, q2.moe.top_k, q2.moe.n_shared,
            q2.moe.d_ff_expert) == (60, 4, 4, 1408)


def test_param_counts_in_published_ballpark():
    """Analytic param counts should be within ~25% of the published sizes."""
    targets = {
        "command-r-35b": 30e9,   # assigned GQA-kv8 config of the 35b family
        "deepseek-67b": 67e9,
        "phi3-mini-3.8b": 3.8e9, "qwen2-1.5b": 1.5e9,
        # the assigned 48L/d2048/pf2 config lands at ~2B; the "1.3b" label
        # is the published family name (DESIGN.md §6)
        "xlstm-1.3b": 2.0e9,
        "qwen3-moe-235b-a22b": 235e9,
        "hymba-1.5b": 1.5e9,
    }
    for arch, target in targets.items():
        got = get_config(arch).param_count()
        assert 0.7 * target < got < 1.35 * target, \
            (arch, got / 1e9, target / 1e9)
