"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.launch import mesh as mesh_mod
from repro.core import balance, hardware
from repro.core.config import ArchConfig, AttnConfig
from repro.data import synth_batch
from repro.kernels import ops, ref
from repro.core.async_pipeline import Strategy

SET = settings(max_examples=20, deadline=None)


# --- stream kernel: closed form (0.5x + 0.5)^n -> fixed point 1 -------------

@SET
@given(iters=st.integers(0, 12),
       seed=st.integers(0, 2 ** 16),
       strategy=st.sampled_from(list(Strategy)))
def test_stream_closed_form(iters, seed, strategy):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (32, 128), jnp.float32)
    got = np.asarray(ops.stream(x, iters=iters, strategy=strategy))
    # closed form: f^n(x) = 2^-n x + (1 - 2^-n)
    a = 0.5 ** iters
    np.testing.assert_allclose(got, a * np.asarray(x) + (1 - a), rtol=1e-5,
                               atol=1e-6)
    assert got.min() >= min(float(x.min()), 1.0) - 1e-6   # contraction to 1


# --- pathfinder: DP result bounded by row sums -------------------------------

@SET
@given(seed=st.integers(0, 2 ** 16))
def test_pathfinder_bounds(seed):
    wall = jax.random.randint(jax.random.PRNGKey(seed), (17, 128), 0, 10,
                              jnp.int32)
    out = np.asarray(ops.pathfinder(wall))[0]
    # any path sums rows-many values in [0, 9]
    assert out.min() >= int(np.asarray(wall)[0].min())
    assert out.max() <= 9 * 17
    # monotone: adding a constant to the wall shifts the result exactly
    out2 = np.asarray(ops.pathfinder(wall + 1))[0]
    np.testing.assert_array_equal(out2, out + 17)


# --- expected speedup: min property + identity -------------------------------

@SET
@given(a=st.sampled_from(list(hardware.CATALOG)),
       b=st.sampled_from(list(hardware.CATALOG)))
def test_expected_speedup_properties(a, b):
    ca, cb = hardware.get_chip(a), hardware.get_chip(b)
    if ca.tflops_f32 == 0 or ca.mem_bw_gbs == 0:
        return
    t = balance.expected_speedup(ca, cb)
    assert t <= cb.tflops_f32 / ca.tflops_f32 + 1e-9
    assert t <= cb.mem_bw_gbs / ca.mem_bw_gbs + 1e-9
    assert balance.expected_speedup(ca, ca) == 1.0


# --- roofline attainable performance is monotone in intensity ----------------

@SET
@given(i1=st.floats(0.01, 1000), i2=st.floats(0.01, 1000))
def test_roofline_monotone(i1, i2):
    chip = hardware.get_chip("A100")
    lo, hi = min(i1, i2), max(i1, i2)
    assert balance.attainable_flops(lo, chip) <= \
        balance.attainable_flops(hi, chip) + 1e-6


# --- data pipeline: determinism + label shift over arbitrary params ----------

@SET
@given(seed=st.integers(0, 2 ** 20), step=st.integers(0, 10 ** 6),
       batch=st.integers(1, 4))
def test_synth_batch_properties(seed, step, batch):
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=32, vocab=251,
                     attn=AttnConfig(chunk=8))
    b1 = synth_batch(cfg, batch=batch, seq=16, seed=seed, step=step)
    b2 = synth_batch(cfg, batch=batch, seq=16, seed=seed, step=step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < cfg.vocab


# --- attention: chunk-size invariance ----------------------------------------

@SET
@given(seed=st.integers(0, 2 ** 16), chunk=st.sampled_from([4, 8, 16, 32]))
def test_attention_chunk_invariance(seed, chunk):
    from repro.models import attention as attn
    b, s, h, d = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    idx = attn.kv_index_map(h, h, h)
    a1 = attn.attend_chunked(q, k, v, idx, causal=True, window=0, chunk=chunk)
    a2 = attn.attend_chunked(q, k, v, idx, causal=True, window=0, chunk=s)
    np.testing.assert_allclose(a1, a2, rtol=2e-5, atol=2e-5)


# --- NW max-plus scan: result invariant to tile_rows --------------------------

@SET
@given(seed=st.integers(0, 2 ** 16),
       tile_rows=st.sampled_from([4, 8, 16]))
def test_nw_tile_invariance(seed, tile_rows):
    n = 32
    scores = jax.random.randint(jax.random.PRNGKey(seed), (n, n), -3,
                                4).astype(jnp.float32)
    got = ops.nw(scores, penalty=5, tile_rows=tile_rows)
    want = ref.nw_ref(scores, 5)
    np.testing.assert_allclose(got, want, atol=1e-4)


# --- zero1 spec: inserts data axes only once, only when divisible -------------

@SET
@given(dim0=st.integers(1, 64), dim1=st.integers(1, 64))
def test_zero1_spec_valid(dim0, dim1):
    from repro.optim import zero1_spec
    from repro.distributed.sharding import ShardingRules
    mesh = mesh_mod.make_mesh((1,), ("data",))
    rules = ShardingRules(mesh, {"batch": ("data",), "mlp": None})
    spec = zero1_spec(("mlp", None), (dim0, dim1), rules)
    flat = [a for s in spec for a in
            ((s,) if not isinstance(s, tuple) else s) if a]
    assert len(flat) == len(set(flat))      # no duplicate mesh axes
