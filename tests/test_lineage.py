"""Lineage validation tests: the committed Hopper reference table against
the live catalog (the CI gate must pass from a clean checkout), the verdict
banding logic, reference-table schema rejection, and the CLI exit codes."""
import json
import os

import pytest

from repro.bench import cli, lineage
from repro.core import hardware

REF = lineage.default_reference_path()


# --- the committed reference table ------------------------------------------

def test_committed_reference_loads_and_validates_within_band():
    """The acceptance loop: every committed published pair — the paper's
    K80→A100 Table-1 expectations and the Luo et al. Hopper numbers — is
    reproduced by the catalog within its band."""
    pairs = lineage.load_reference(REF)
    assert len(pairs) >= 6
    names = {(p.old, p.new, p.precision) for p in pairs}
    assert ("K80", "P100", "f32") in names
    assert ("V100", "A100", "f32") in names
    assert ("A100", "H100-SXM", "f32") in names
    verdicts = lineage.validate(pairs)
    assert all(v.verdict == "within-band" for v in verdicts), [
        (v.old, v.new, v.precision, v.verdict, v.rel_dev) for v in verdicts
        if v.verdict != "within-band"]
    doc = lineage.to_doc(verdicts)
    assert doc["ok"] is True
    assert doc["counts"]["within-band"] == len(pairs)


def test_a100_to_h100_pair_is_bandwidth_bound_in_reference():
    verdicts = lineage.validate(lineage.load_reference(REF))
    sxm = [v for v in verdicts
           if (v.old, v.new, v.precision) == ("A100", "H100-SXM", "f32")]
    assert len(sxm) == 1
    assert sxm[0].binds == "bandwidth"
    assert sxm[0].expected == pytest.approx(2.156, abs=0.01)


# --- banding / verdict logic ------------------------------------------------

def _pair(published, band=0.05, old="V100", new="A100"):
    return lineage.LineagePair(old=old, new=new, published=published,
                               band=band)


def test_verdict_banding_over_under_within():
    # catalog V100→A100 expectation is ~1.379
    within, = lineage.validate([_pair(1.38)])
    assert within.verdict == "within-band" and within.ok
    under, = lineage.validate([_pair(2.0)])       # catalog predicts less
    assert under.verdict == "under" and not under.ok
    over, = lineage.validate([_pair(1.0)])        # catalog predicts more
    assert over.verdict == "over" and not over.ok
    doc = lineage.to_doc([within, under, over])
    assert doc["ok"] is False
    assert doc["counts"] == {"within-band": 1, "over": 1, "under": 1}


def test_band_edges_judge_deviation_not_direction():
    from repro.core import balance
    expected = balance.expected_speedup(hardware.get_chip("V100"),
                                        hardware.get_chip("A100"))
    just_in, = lineage.validate([_pair(expected / 1.04, band=0.05)])
    assert just_in.verdict == "within-band"       # +4% dev inside ±5%
    just_out, = lineage.validate([_pair(expected / 1.06, band=0.05)])
    assert just_out.verdict == "over"             # +6% dev outside ±5%
    low_out, = lineage.validate([_pair(expected * 1.06, band=0.05)])
    assert low_out.verdict == "under"


def test_lineage_chain_walks_datacenter_arc():
    chain = lineage.lineage_chain()
    hops = [(v.old, v.new) for v in chain]
    arc = hardware.DATACENTER_LINEAGE
    assert hops == list(zip(arc, arc[1:]))
    assert all(v.verdict == "expected" for v in chain)
    assert all(v.expected > 1.0 for v in chain)


# --- reference-table hygiene ------------------------------------------------

def test_reference_rejects_wrong_kind_schema_and_unknown_chip(tmp_path):
    base = json.load(open(REF))

    def write(doc):
        p = tmp_path / "ref.json"
        p.write_text(json.dumps(doc))
        return str(p)

    with pytest.raises(ValueError, match="kind"):
        lineage.load_reference(write({**base, "kind": "bench-report"}))
    with pytest.raises(ValueError, match="schema"):
        lineage.load_reference(write({**base, "schema": 99}))
    bogus = dict(base)
    bogus["pairs"] = [{"old": "K80", "new": "H100-SXMM",
                       "published": 2.0, "band": 0.1}]
    with pytest.raises(ValueError, match="unknown chip"):
        lineage.load_reference(write(bogus))
    with pytest.raises(ValueError, match="no pairs"):
        lineage.load_reference(write({**base, "pairs": []}))


# --- CLI gate ---------------------------------------------------------------

def test_cli_lineage_gate_passes_and_writes_doc(tmp_path, capsys):
    out = str(tmp_path / "LINEAGE.json")
    rc = cli.main(["lineage", "--json", out])
    assert rc == 0
    doc = json.load(open(out))
    assert doc["kind"] == "lineage-validation"
    assert doc["ok"] is True
    assert doc["chain"], "chain rows feed the make_report arc table"
    assert "within-band" in capsys.readouterr().out


def test_cli_lineage_gate_fails_on_drifted_reference(tmp_path, capsys):
    base = json.load(open(REF))
    base["pairs"][0]["published"] = 10.0          # catalog can't reach this
    drifted = tmp_path / "drifted.json"
    drifted.write_text(json.dumps(base))
    rc = cli.main(["lineage", "--reference", str(drifted)])
    assert rc == 1
    err = capsys.readouterr().err
    assert "drifted" in err or "under" in err


def test_cli_lineage_missing_reference_is_a_usage_error(tmp_path, capsys):
    rc = cli.main(["lineage", "--reference",
                   str(tmp_path / "nope.json")])
    assert rc == 2
