"""Roofline engine tests: loop-aware HLO cost analysis (the reason this
module exists: XLA's cost_analysis counts a while body ONCE), collective
parsing, and report arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hardware, roofline
from repro.core.hlo_cost import (analyze_hlo, cost_with_loops,
                                  xla_cost_analysis)


def test_scan_flops_are_trip_scaled():
    def f_scan(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y.sum()

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    compiled = jax.jit(f_scan).lower(w, x).compile()
    ours = cost_with_loops(compiled)
    analytic = 2 * 8 * 32 * 128 * 128
    assert abs(ours.flops - analytic) / analytic < 0.05
    # XLA's own analysis undercounts by ~the trip count — the motivating bug
    xla = xla_cost_analysis(compiled).get("flops", 0)
    assert xla < analytic / 4


def test_nonscan_flops_match_xla():
    def g(a, b):
        return jnp.tanh(a @ b).sum()
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(g).lower(s, s).compile()
    ours = cost_with_loops(compiled)
    xla = xla_cost_analysis(compiled).get("flops", 0)
    assert abs(ours.flops - xla) / xla < 0.05


def test_loop_invariant_weights_counted_once():
    """A weight reused across scan iterations streams to VMEM once."""
    def f(w, xs):
        def body(_, x):
            return None, jnp.tanh(x @ w)
        _, ys = jax.lax.scan(body, None, xs)
        return ys.sum()

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)      # 256 KiB, resident
    xs = jax.ShapeDtypeStruct((64, 8, 256), jnp.float32)
    c = cost_with_loops(jax.jit(f).lower(w, xs).compile())
    w_bytes = 256 * 256 * 4
    # if charged per trip the weight alone would be 64 * 256KiB = 16 MiB
    assert c.bytes_fused < 40 * w_bytes


def test_collective_parse_ring_bytes():
    hlo = """
HloModule test

ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%a), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[512,64]{1,0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %out = f32[128,64]{1,0} slice(%ag), slice={[0:128], [0:64]}
}
"""
    ops = roofline.parse_collectives(hlo)
    kinds = {o.kind for o in ops}
    assert kinds == {"all-reduce", "all-gather"}
    ar = next(o for o in ops if o.kind == "all-reduce")
    n_bytes = 128 * 64 * 4
    assert ar.wire_bytes == pytest.approx(2 * n_bytes * 3 / 4)
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.wire_bytes == pytest.approx(n_bytes * 3)


def test_collectives_inside_loops_scaled():
    hlo = """
HloModule test

%body (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[64,64]{1,0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%i2, %ar)
}

%cond (arg2: (s32[], f32[64,64])) -> pred[] {
  %arg2 = (s32[], f32[64,64]) parameter(0)
  %j = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}

ENTRY %main (x0: f32[64,64]) -> f32[64,64] {
  %x0 = f32[64,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[64,64]) tuple(%z, %x0)
  %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""
    c = analyze_hlo(hlo)
    assert c.collective_counts.get("all-reduce", 0) == 10
    per = 2 * (64 * 64 * 4) * (1 / 2)
    assert c.wire_bytes == pytest.approx(10 * per)


def test_report_terms_and_bottleneck():
    rep = roofline.RooflineReport(
        arch="a", shape="s", mesh="m", n_chips=256,
        hlo_flops=hardware.PEAK_FLOPS,          # 1 s of compute
        hlo_bytes=hardware.HBM_BW / 2,          # 0.5 s of memory
        collective_wire_bytes=hardware.ICI_BW * 2,  # 2 s of wire
        model_flops=hardware.PEAK_FLOPS / 2)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.t_memory == pytest.approx(0.5)
    assert rep.t_collective == pytest.approx(2.0)
    assert rep.bottleneck == "collective"
    assert rep.t_bound == pytest.approx(2.0)
    assert rep.useful_flops_ratio == pytest.approx(0.5)
    assert rep.roofline_fraction == pytest.approx(0.25)


def test_dtype_bytes_table():
    assert roofline.shape_bytes("f32", "8,4") == 128
    assert roofline.shape_bytes("bf16", "8,4") == 64
    assert roofline.shape_bytes("pred", "10") == 10
    assert roofline.shape_bytes("f32", "") == 4   # scalar
