"""Continuous-batching serving subsystem: paged KV cache allocator
invariants, block-table attention vs the dense cache path, greedy output
bit-identity across scheduling (arrival order, batch size, scheduler
choice, solo oracle), the jit-recompile cap, the prefill key-split fix,
arrival-trace determinism, and the serve/* bench rows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ArchConfig, AttnConfig
from repro.distributed.sharding import split_tree
from repro.launch.serve import ServingLoop
from repro.models import attention as attn
from repro.models import build_model
from repro.models import transformer as tfm
from repro.serve import (CohortScheduler, ContinuousScheduler, PagedKVCache,
                         Request, make_trace, next_pow2)


def _cfg(vocab=128):
    return ArchConfig(name="sv", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=vocab,
                      attn=AttnConfig(chunk=16))


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(1)))
    return cfg, model, params


def _reqs(cfg, lens, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        (int(n),)).astype(np.int32),
                    max_new=int(m))
            for i, (n, m) in enumerate(zip(lens, max_new))]


def _continuous(cfg, params, batch):
    return ContinuousScheduler(cfg, params, batch=batch, max_seq=64,
                               block_len=8)


# ---------------------------------------------------------------------------
# PagedKVCache allocator
# ---------------------------------------------------------------------------

def test_paged_cache_alloc_free_reuse():
    cache = PagedKVCache(_cfg(), batch=2, total_tokens=64, max_seq=32,
                         block_len=8)
    n_free0 = cache.free_blocks
    ids = cache.admit(0, prefill_tokens=16, lifetime_tokens=24)
    assert len(ids) == 2 and 0 not in ids          # block 0 is scratch
    assert cache.free_blocks == n_free0 - 2
    assert cache.reserved_blocks == 1              # 24 tokens -> 3 blocks
    assert list(cache.tables[0, :2]) == ids

    cache.append(0, 16)                            # crosses into block 3
    assert cache.reserved_blocks == 0
    assert cache.free_blocks == n_free0 - 3
    cache.append(0, 17)                            # same block: no alloc
    assert cache.free_blocks == n_free0 - 3

    freed = cache.free_slot(0)
    assert len(freed) == 3 and set(ids) <= set(freed)
    assert cache.free_blocks == n_free0
    assert cache.used_blocks == 0
    assert (cache.tables[0] == -1).all()
    # freed blocks' device position rows were cleared
    pos = np.asarray(cache.state.pos)
    for b in freed:
        assert (pos[b] == -1).all()

    # LIFO reuse: the next admission gets just-freed blocks back
    ids2 = cache.admit(1, prefill_tokens=8, lifetime_tokens=8)
    assert ids2[0] in freed


def test_paged_cache_admission_when_full():
    cache = PagedKVCache(_cfg(), batch=4, total_tokens=32, max_seq=32,
                         block_len=8)                  # 4 usable blocks
    assert cache.can_admit(24)
    cache.admit(0, prefill_tokens=16, lifetime_tokens=24)  # 3 blocks
    assert cache.can_admit(8)
    assert not cache.can_admit(16)      # only 1 unreserved block left
    cache.admit(1, prefill_tokens=8, lifetime_tokens=8)
    assert not cache.can_admit(1)       # arena exhausted
    cache.free_slot(0)
    assert cache.can_admit(24)          # blocks + reservation returned
    # over-reserving beyond the guarantee is an error, not a deadlock
    with pytest.raises(RuntimeError):
        cache.admit(2, prefill_tokens=32, lifetime_tokens=64)


def test_paged_cache_append_guards():
    cache = PagedKVCache(_cfg(), batch=1, total_tokens=32, max_seq=32,
                         block_len=8)
    cache.admit(0, prefill_tokens=8, lifetime_tokens=8)   # no reservation
    with pytest.raises(RuntimeError, match="reserved lifetime"):
        cache.append(0, 8)              # needs a block it never reserved


def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 8, 9, 17)] == \
        [1, 2, 4, 8, 16, 32]


# ---------------------------------------------------------------------------
# Block-table attention vs the dense cache path
# ---------------------------------------------------------------------------

def test_attend_paged_matches_attend_decode():
    """Gathering (k, v, pos) through a block table must reproduce the
    dense ragged-decode attention bit-for-bit."""
    rng = np.random.default_rng(0)
    B, W, KV, HP, HD, BL = 2, 16, 2, 4, 8, 4
    k = rng.standard_normal((B, W, KV, HD)).astype(np.float32)
    v = rng.standard_normal((B, W, KV, HD)).astype(np.float32)
    q = rng.standard_normal((B, 1, HP, HD)).astype(np.float32)
    # ragged: slot 0 holds 10 rows, slot 1 holds 6
    pos = np.full((B, W), -1, np.int32)
    pos[0, :10] = np.arange(10)
    pos[1, :6] = np.arange(6)
    q_position = jnp.asarray([10, 6], jnp.int32)
    idx_map = attn.kv_index_map(HP, KV, HP)

    dense = attn.attend_decode(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), jnp.asarray(pos), idx_map,
                               q_position=q_position)

    # scatter the same rows into a block arena: slot 0 -> blocks 1..3,
    # slot 1 -> blocks 4..5 (table padded with -1)
    n_blocks = 7
    kb = np.zeros((n_blocks, BL, KV, HD), np.float32)
    vb = np.zeros((n_blocks, BL, KV, HD), np.float32)
    pb = np.full((n_blocks, BL), -1, np.int32)
    table = np.full((B, 4), -1, np.int32)
    table[0, :3] = [1, 2, 3]
    table[1, :2] = [4, 5]
    for s in range(B):
        for j, b in enumerate(t for t in table[s] if t >= 0):
            kb[b] = k[s, j * BL:(j + 1) * BL]
            vb[b] = v[s, j * BL:(j + 1) * BL]
            pb[b] = pos[s, j * BL:(j + 1) * BL]
    # poison the scratch block: a correct gather never attends it
    kb[0] += 100.0
    pb[0] = 0

    paged = attn.attend_paged(jnp.asarray(q), jnp.asarray(kb),
                              jnp.asarray(vb), jnp.asarray(pb),
                              jnp.asarray(table), idx_map,
                              q_position=q_position)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))


def test_forward_paged_decode_rejects_unpaged_family():
    cfg = ArchConfig(name="ssm", family="ssm", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64)
    assert build_model(cfg).decode_paged is None
    paged = tfm.init_paged_state(_cfg(), 2, 8)
    with pytest.raises(NotImplementedError):
        tfm.forward_paged_decode({}, cfg, jnp.zeros((1, 1), jnp.int32),
                                 paged, jnp.zeros((1, 1), jnp.int32),
                                 jnp.zeros((1,), jnp.int32))


# ---------------------------------------------------------------------------
# Greedy bit-identity across scheduling
# ---------------------------------------------------------------------------

def test_continuous_matches_solo_oracle_and_orderings(served):
    """Continuous batching must not change greedy outputs: same tokens
    for every request whether served alone, in a different arrival
    order, or at a different batch size."""
    cfg, model, params = served
    reqs = lambda: _reqs(cfg, lens=(12, 7, 9), max_new=(3, 4, 3))

    base = _continuous(cfg, params, 2).run(reqs())
    oracle = {}
    for r in reqs():
        oracle.update(_continuous(cfg, params, 1).run([r]))
    assert base == oracle

    reordered = _continuous(cfg, params, 2).run(reqs()[::-1])
    assert reordered == base

    wider = _continuous(cfg, params, 3).run(reqs())
    assert wider == base

    # teacher-forcing reference for one member
    r0 = reqs()[0]
    toks = list(r0.prompt)
    for _ in range(r0.max_new):
        logits = model.forward(
            params, {"tokens": jnp.asarray([toks]),
                     "labels": jnp.zeros((1, len(toks)), jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    assert base[0] == toks[len(r0.prompt):]


def test_continuous_matches_cohort_equal_lengths(served):
    """For equal-length prompts (no cohort padding) the two schedulers
    are numerically identical under greedy decoding."""
    cfg, _, params = served
    mk = lambda: _reqs(cfg, lens=(10, 10), max_new=(3, 3), seed=2)
    cont = _continuous(cfg, params, 2).run(mk())
    coh = CohortScheduler(cfg, params, batch=2).run(mk())
    assert cont == coh


def test_continuous_slot_refill_under_arrivals(served):
    """More requests than slots + staggered arrivals: every request is
    served, outputs still match the solo oracle, and the arena drains."""
    cfg, _, params = served
    mk = lambda: [Request(uid=i, prompt=p.prompt, max_new=p.max_new,
                          arrival=float(i))
                  for i, p in enumerate(
                      _reqs(cfg, lens=(11, 6, 9, 7, 8), max_new=(2, 4, 3,
                                                                 2, 3),
                            seed=3))]
    sched = _continuous(cfg, params, 2)
    out = sched.run(mk())
    assert set(out) == set(range(5))
    oracle = {}
    for r in mk():
        r.arrival = 0.0
        oracle.update(_continuous(cfg, params, 1).run([r]))
    assert out == oracle
    assert sched.cache.used_blocks == 0
    assert sched.cache.free_blocks == sched.cache.n_blocks - 1
    snap = {row["name"]: row for row in sched.metrics.snapshot()}
    assert snap["serve.requests_total"]["value"] == 5
    assert snap["serve.tokens_total"]["value"] == 2 + 4 + 3 + 2 + 3


# ---------------------------------------------------------------------------
# Satellite fixes: recompile cap + prefill key split
# ---------------------------------------------------------------------------

def test_cohort_budget_bucketing_caps_recompiles(served):
    """Prompt lengths whose KV budgets land in the same power-of-two
    bucket must share one compiled (prefill, decode) pair."""
    cfg, _, params = served
    sched = CohortScheduler(cfg, params, batch=1, max_new=4)
    sched.run(_reqs(cfg, lens=(20,), max_new=(2,), seed=4))
    sched.run(_reqs(cfg, lens=(24,), max_new=(2,), seed=5))
    # budgets 25 and 29 both bucket to 32 -> one compiled pair
    assert len(sched._fns) == 1


def test_cohort_prefill_splits_sampling_key(served):
    """Regression: the prefill sample must consume a split of the loop
    key, not the key itself — a prefill-only run must advance the key."""
    cfg, _, params = served
    sched = CohortScheduler(cfg, params, batch=1, seed=7)
    key0 = np.asarray(sched.key).copy()
    sched.run(_reqs(cfg, lens=(8,), max_new=(1,), seed=6),
              temperature=1.0, max_steps=1)
    assert not np.array_equal(np.asarray(sched.key), key0)
    # and two consecutive prefill-only runs draw from different streams
    out1 = sched.run(_reqs(cfg, lens=(8,), max_new=(1,), seed=6),
                     temperature=1.0, max_steps=1)
    out2 = sched.run(_reqs(cfg, lens=(8,), max_new=(1,), seed=6),
                     temperature=1.0, max_steps=1)
    assert not np.array_equal(np.asarray(sched.key), key0)
    assert out1.keys() == out2.keys()


def test_continuous_sampling_is_scheduling_independent(served):
    """Per-request fold_in keys: sampled (temperature > 0) outputs don't
    depend on batch size or arrival order."""
    cfg, _, params = served
    mk = lambda: _reqs(cfg, lens=(9, 12, 7), max_new=(3, 3, 3), seed=8)
    a = ContinuousScheduler(cfg, params, batch=3, max_seq=64, block_len=8,
                            seed=11).run(mk(), temperature=0.7)
    b = ContinuousScheduler(cfg, params, batch=1, max_seq=64, block_len=8,
                            seed=11).run(mk()[::-1], temperature=0.7)
    assert a == b


# ---------------------------------------------------------------------------
# Arrival traces + launch wrapper + bench rows
# ---------------------------------------------------------------------------

def test_traces_deterministic_and_shaped():
    a = make_trace("poisson", 8, vocab=64, rate=0.5, seed=3)
    b = make_trace("poisson", 8, vocab=64, rate=0.5, seed=3)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[-1] > 0
    # same seed, different arrival process -> identical request shapes
    u = make_trace("uniform", 8, vocab=64, rate=0.5, seed=3)
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, u))
    bursty = make_trace("bursty", 8, vocab=64, rate=0.5, burst=4, seed=3)
    assert bursty[0].arrival == bursty[1].arrival    # burst members co-arrive
    with pytest.raises(ValueError):
        make_trace("laplace", 4, vocab=64)


def test_serving_loop_falls_back_to_cohort():
    cfg = ArchConfig(name="ssm", family="ssm", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=2, scheduler="continuous")
    assert loop.scheduler_kind == "cohort"
    out = loop.run(_reqs(cfg, lens=(8, 8), max_new=(2, 2)))
    assert all(len(v) == 2 for v in out.values())


def test_serve_scenarios_registered_and_runnable(served):
    from repro.bench.runner import RunOptions, project_scenario, sweep
    from repro.bench.scenario import ServeScenario, get_scenario, scenarios

    names = [s.name for s in scenarios(tag="serve")]
    for arrival in ("uniform", "poisson", "bursty"):
        for sched in ("continuous", "cohort"):
            assert f"serve/{arrival}/{sched}" in names
    # serving cells are excluded from the smoke kernel sweep
    assert not [s for s in scenarios(smoke=True) if s.is_serving]
    with pytest.raises(ValueError):
        project_scenario(get_scenario("serve/uniform/continuous"), "A100")

    sc = ServeScenario(
        name="serve/test/tiny", shape=(2, 3),
        workload={"scheduler": "continuous", "arrival": "uniform",
                  "n_requests": 3, "batch": 2, "rate": 1.0,
                  "prompt_lens": [5, 10], "max_new": [2, 3], "seed": 0,
                  "block_len": 8},
        tags=("serve",), section="serve")
    report = sweep([sc], chips=["A100"], opts=RunOptions(emit=None))
    rows = [r for r in report.results if r.scenario == "serve/test/tiny"]
    assert len(rows) == 1               # measured only: no projection rows
    m = rows[0].metrics
    assert rows[0].kind == "measured"
    assert m["us_median"] > 0 and len(m["times_us"]) >= 2
    assert m["tokens"] > 0 and m["requests"] == 3
    assert 0 < m["occupancy_mean"] <= 1
    assert m["tokens_per_s"] > 0
