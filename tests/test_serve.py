"""Continuous-batching serving subsystem: paged KV cache allocator
invariants, block-table attention vs the dense cache path, greedy output
bit-identity across scheduling (arrival order, batch size, scheduler
choice, solo oracle), the jit-recompile cap, the prefill key-split fix,
arrival-trace determinism, and the serve/* bench rows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dataclasses

from repro.core.config import ArchConfig, AttnConfig
from repro.distributed.sharding import split_tree
from repro.launch.serve import ServingLoop
from repro.models import attention as attn
from repro.models import build_model
from repro.models import transformer as tfm
from repro.serve import (CohortScheduler, ContinuousScheduler, PagedKVCache,
                         Request, block_hashes, make_trace, next_pow2)


def _cfg(vocab=128):
    return ArchConfig(name="sv", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=vocab,
                      attn=AttnConfig(chunk=16))


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(1)))
    return cfg, model, params


def _reqs(cfg, lens, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        (int(n),)).astype(np.int32),
                    max_new=int(m))
            for i, (n, m) in enumerate(zip(lens, max_new))]


def _continuous(cfg, params, batch):
    return ContinuousScheduler(cfg, params, batch=batch, max_seq=64,
                               block_len=8)


# ---------------------------------------------------------------------------
# PagedKVCache allocator
# ---------------------------------------------------------------------------

def test_paged_cache_alloc_free_reuse():
    cache = PagedKVCache(_cfg(), batch=2, total_tokens=64, max_seq=32,
                         block_len=8)
    n_free0 = cache.free_blocks
    ids = cache.admit(0, prefill_tokens=16, lifetime_tokens=24)
    assert len(ids) == 2 and 0 not in ids          # block 0 is scratch
    assert cache.free_blocks == n_free0 - 2
    assert cache.reserved_blocks == 1              # 24 tokens -> 3 blocks
    assert list(cache.tables[0, :2]) == ids

    cache.append(0, 16)                            # crosses into block 3
    assert cache.reserved_blocks == 0
    assert cache.free_blocks == n_free0 - 3
    cache.append(0, 17)                            # same block: no alloc
    assert cache.free_blocks == n_free0 - 3

    freed = cache.free_slot(0)
    assert len(freed) == 3 and set(ids) <= set(freed)
    assert cache.free_blocks == n_free0
    assert cache.used_blocks == 0
    assert (cache.tables[0] == -1).all()
    # freed blocks' device position rows were cleared
    pos = np.asarray(cache.state.pos)
    for b in freed:
        assert (pos[b] == -1).all()

    # LIFO reuse: the next admission gets just-freed blocks back
    ids2 = cache.admit(1, prefill_tokens=8, lifetime_tokens=8)
    assert ids2[0] in freed


def test_paged_cache_admission_when_full():
    cache = PagedKVCache(_cfg(), batch=4, total_tokens=32, max_seq=32,
                         block_len=8)                  # 4 usable blocks
    assert cache.can_admit(24)
    cache.admit(0, prefill_tokens=16, lifetime_tokens=24)  # 3 blocks
    assert cache.can_admit(8)
    assert not cache.can_admit(16)      # only 1 unreserved block left
    cache.admit(1, prefill_tokens=8, lifetime_tokens=8)
    assert not cache.can_admit(1)       # arena exhausted
    cache.free_slot(0)
    assert cache.can_admit(24)          # blocks + reservation returned
    # over-reserving beyond the guarantee is an error, not a deadlock
    with pytest.raises(RuntimeError):
        cache.admit(2, prefill_tokens=32, lifetime_tokens=64)


def test_paged_cache_append_guards():
    cache = PagedKVCache(_cfg(), batch=1, total_tokens=32, max_seq=32,
                         block_len=8)
    cache.admit(0, prefill_tokens=8, lifetime_tokens=8)   # no reservation
    with pytest.raises(RuntimeError, match="reserved lifetime"):
        cache.append(0, 8)              # needs a block it never reserved


def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 8, 9, 17)] == \
        [1, 2, 4, 8, 16, 32]


def test_free_slot_releases_midprefill_reservation():
    """Regression: cancelling a slot between admission and its first
    append must return the lifetime-*reserved* (never-allocated) blocks
    too — repeated admit-then-cancel at full reservation pressure must
    not leak a single block."""
    cache = PagedKVCache(_cfg(), batch=2, total_tokens=64, max_seq=64,
                         block_len=8)
    free0 = cache.free_blocks
    for _ in range(4 * cache.n_blocks):      # far past arena capacity
        cache.admit(0, prefill_tokens=8, lifetime_tokens=64)
        cache.free_slot(0)                   # cancelled mid-prefill
        assert cache.free_blocks == free0
        assert cache.reserved_blocks == 0
        assert cache.used_blocks == 0
    # same invariant through the shared-admission path
    cache2 = PagedKVCache(_cfg(), batch=2, total_tokens=64, max_seq=64,
                          block_len=8, prefix_cache=True)
    toks = np.arange(40, dtype=np.int32)
    free0 = cache2.free_blocks
    for _ in range(4 * cache2.n_blocks):
        cache2.admit_shared(0, toks, 64, max_match_rows=32)
        cache2.free_slot(0)
        assert cache2.free_blocks + cache2.evictable_blocks == free0
        assert cache2.reserved_blocks == 0
        assert cache2.used_blocks == 0


# ---------------------------------------------------------------------------
# Prefix sharing: content addressing, refcounts, CoW, retention
# ---------------------------------------------------------------------------

def test_block_hashes_chain_property():
    toks = np.arange(32, dtype=np.int32)
    h2 = block_hashes(toks[:16], 2, 8)
    h4 = block_hashes(toks, 4, 8)
    assert h4[:2] == h2                     # prefix of hashes = hash of prefix
    assert len(set(h4)) == 4
    # a flipped token in block 0 changes every chain hash after it
    other = toks.copy()
    other[0] += 1
    assert all(a != b for a, b in zip(block_hashes(other, 4, 8), h4))
    # a flipped token in block 2 leaves blocks 0-1 alone
    other2 = toks.copy()
    other2[16] += 1
    assert block_hashes(other2, 4, 8)[:2] == h2
    with pytest.raises(ValueError):
        block_hashes(toks[:10], 2, 8)


def _prefix_cache(batch=3, total=80, max_seq=48):
    return PagedKVCache(_cfg(), batch=batch, total_tokens=total,
                        max_seq=max_seq, block_len=8, prefix_cache=True)


def test_admit_shared_maps_registered_prefix_by_reference():
    cache = _prefix_cache()
    toks = np.arange(100, 132, dtype=np.int32)      # 4 full blocks
    cache.admit(0, prefill_tokens=32, lifetime_tokens=32)
    producer = list(cache._slot_blocks[0])
    cache.register_prefix(0, toks, 32)
    assert cache.match_prefix(toks, 32) == producer

    # consumer with the same 32-token prefix + an 8-token private tail
    toks2 = np.concatenate([toks, np.arange(8, dtype=np.int32)])
    m = cache.admit_shared(1, toks2, lifetime_tokens=48, max_match_rows=32)
    assert m == 32
    assert cache._slot_blocks[1] == producer        # mapped, not copied
    assert all(cache._ref[b] == 2 for b in producer)
    # reservation shrank by the 4 matched blocks: 48 tokens = 6 blocks
    assert cache._slot_reserved[1] == 2
    assert cache.hit_tokens == 32 and cache.miss_tokens == 8
    assert cache.cache_hit_ratio == pytest.approx(32 / 40)

    # granule rounding: a 4-block match capped to 2-chunk (16-row) units
    cache.free_slot(1)
    m = cache.admit_shared(1, toks2, lifetime_tokens=48,
                           max_match_rows=32, granule_rows=16)
    assert m == 32                                  # 32 is a 16-multiple
    cache.free_slot(1)
    m = cache.admit_shared(2, toks2[:28], lifetime_tokens=28,
                           max_match_rows=24, granule_rows=16)
    assert m == 16                                  # 3 blocks round to 2


def test_free_slot_retains_registered_blocks_until_evicted():
    cache = _prefix_cache(batch=2, total=40, max_seq=40)  # 6 blocks
    toks = np.arange(16, dtype=np.int32)
    cache.admit(0, prefill_tokens=16, lifetime_tokens=16)
    shared = list(cache._slot_blocks[0])
    cache.register_prefix(0, toks, 16)
    free_before = cache.free_blocks
    cache.free_slot(0)
    # registered blocks park in the evictable pool, not the free list
    assert cache.free_blocks == free_before
    assert cache.evictable_blocks == 2
    # a later match revives them by reference
    m = cache.admit_shared(0, toks, lifetime_tokens=16, max_match_rows=16)
    assert m == 16 and cache.evictable_blocks == 0
    assert cache._slot_blocks[0] == shared
    cache.free_slot(0)

    # exhausting the free list forces LRU eviction of the retained pool
    cache.admit(1, prefill_tokens=40, lifetime_tokens=40)   # 5 blocks
    assert cache.evictable_blocks < 2       # at least one was reclaimed
    evicted = [b for b in shared if b in cache._slot_blocks[1]]
    assert evicted                          # reused for the new tenant
    assert cache.match_prefix(toks, 16) == []   # registration dropped
    pos = np.asarray(cache.state.pos)
    # eviction scrubbed the reclaimed rows before reuse
    for b in evicted:
        assert (pos[b] == -1).all()


def test_copy_on_write_on_fork():
    cache = _prefix_cache(batch=2, total=80, max_seq=48)
    cache.admit(0, prefill_tokens=20, lifetime_tokens=20)  # partial block 2
    src_blocks = list(cache._slot_blocks[0])
    # give the shared partial block recognizable device content
    pos = np.array(cache.state.pos)
    pos[src_blocks[-1], :4] = np.arange(16, 20)
    cache.state = tfm.PagedState(k=cache.state.k, v=cache.state.v,
                                 pos=jnp.asarray(pos))

    cache.fork_slot(0, 1, src_len=20, lifetime_tokens=28)
    assert cache._slot_blocks[1] == src_blocks
    assert all(cache._ref[b] == 2 for b in src_blocks)
    # 28 tokens = 4 blocks; 3 mapped -> 1 lifetime + 1 CoW reserve
    assert cache._slot_reserved[1] == 2

    cache.append(1, 20)         # lands in the shared partial block
    forked = cache._slot_blocks[1]
    assert forked[:2] == src_blocks[:2]     # full blocks still shared
    assert forked[2] != src_blocks[2]       # partial block went private
    assert cache._ref[src_blocks[2]] == 1   # src keeps the original
    assert cache._slot_blocks[0] == src_blocks
    assert cache.tables[1, 2] == forked[2]
    assert cache._slot_reserved[1] == 1     # CoW drew from the reservation
    # the copy carried the device rows
    pos = np.asarray(cache.state.pos)
    np.testing.assert_array_equal(pos[forked[2]], pos[src_blocks[2]])
    assert (pos[forked[2], :4] == np.arange(16, 20)).all()
    # freeing the fork returns only its private block to the free list
    free_before = cache.free_blocks
    cache.free_slot(1)
    assert cache.free_blocks == free_before + 1
    assert all(cache._ref[b] == 1 for b in src_blocks)


def test_reset_prefix_cache_reclaims_retained_pool():
    cache = _prefix_cache(batch=1, total=40, max_seq=40)
    toks = np.arange(16, dtype=np.int32)
    cache.admit_shared(0, toks, 16, max_match_rows=16)
    cache.extend_to(0, 16)          # shared admission allocates lazily
    cache.register_prefix(0, toks, 16)
    cache.free_slot(0)
    assert cache.evictable_blocks == 2 and cache.miss_tokens == 16
    free_before = cache.free_blocks
    cache.reset_prefix_cache()
    assert cache.evictable_blocks == 0
    assert cache.free_blocks == free_before + 2
    assert cache.hit_tokens == 0 and cache.miss_tokens == 0
    assert cache.match_prefix(toks, 16) == []


# ---------------------------------------------------------------------------
# Block-table attention vs the dense cache path
# ---------------------------------------------------------------------------

def test_attend_paged_matches_attend_decode():
    """Gathering (k, v, pos) through a block table must reproduce the
    dense ragged-decode attention bit-for-bit."""
    rng = np.random.default_rng(0)
    B, W, KV, HP, HD, BL = 2, 16, 2, 4, 8, 4
    k = rng.standard_normal((B, W, KV, HD)).astype(np.float32)
    v = rng.standard_normal((B, W, KV, HD)).astype(np.float32)
    q = rng.standard_normal((B, 1, HP, HD)).astype(np.float32)
    # ragged: slot 0 holds 10 rows, slot 1 holds 6
    pos = np.full((B, W), -1, np.int32)
    pos[0, :10] = np.arange(10)
    pos[1, :6] = np.arange(6)
    q_position = jnp.asarray([10, 6], jnp.int32)
    idx_map = attn.kv_index_map(HP, KV, HP)

    dense = attn.attend_decode(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), jnp.asarray(pos), idx_map,
                               q_position=q_position)

    # scatter the same rows into a block arena: slot 0 -> blocks 1..3,
    # slot 1 -> blocks 4..5 (table padded with -1)
    n_blocks = 7
    kb = np.zeros((n_blocks, BL, KV, HD), np.float32)
    vb = np.zeros((n_blocks, BL, KV, HD), np.float32)
    pb = np.full((n_blocks, BL), -1, np.int32)
    table = np.full((B, 4), -1, np.int32)
    table[0, :3] = [1, 2, 3]
    table[1, :2] = [4, 5]
    for s in range(B):
        for j, b in enumerate(t for t in table[s] if t >= 0):
            kb[b] = k[s, j * BL:(j + 1) * BL]
            vb[b] = v[s, j * BL:(j + 1) * BL]
            pb[b] = pos[s, j * BL:(j + 1) * BL]
    # poison the scratch block: a correct gather never attends it
    kb[0] += 100.0
    pb[0] = 0

    paged = attn.attend_paged(jnp.asarray(q), jnp.asarray(kb),
                              jnp.asarray(vb), jnp.asarray(pb),
                              jnp.asarray(table), idx_map,
                              q_position=q_position)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))


def test_forward_paged_decode_rejects_unpaged_family():
    cfg = ArchConfig(name="ssm", family="ssm", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64)
    assert build_model(cfg).decode_paged is None
    paged = tfm.init_paged_state(_cfg(), 2, 8)
    with pytest.raises(NotImplementedError):
        tfm.forward_paged_decode({}, cfg, jnp.zeros((1, 1), jnp.int32),
                                 paged, jnp.zeros((1, 1), jnp.int32),
                                 jnp.zeros((1,), jnp.int32))


# ---------------------------------------------------------------------------
# Greedy bit-identity across scheduling
# ---------------------------------------------------------------------------

def test_continuous_matches_solo_oracle_and_orderings(served):
    """Continuous batching must not change greedy outputs: same tokens
    for every request whether served alone, in a different arrival
    order, or at a different batch size."""
    cfg, model, params = served
    reqs = lambda: _reqs(cfg, lens=(12, 7, 9), max_new=(3, 4, 3))

    base = _continuous(cfg, params, 2).run(reqs())
    oracle = {}
    for r in reqs():
        oracle.update(_continuous(cfg, params, 1).run([r]))
    assert base == oracle

    reordered = _continuous(cfg, params, 2).run(reqs()[::-1])
    assert reordered == base

    wider = _continuous(cfg, params, 3).run(reqs())
    assert wider == base

    # teacher-forcing reference for one member
    r0 = reqs()[0]
    toks = list(r0.prompt)
    for _ in range(r0.max_new):
        logits = model.forward(
            params, {"tokens": jnp.asarray([toks]),
                     "labels": jnp.zeros((1, len(toks)), jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    assert base[0] == toks[len(r0.prompt):]


def test_continuous_matches_cohort_equal_lengths(served):
    """For equal-length prompts (no cohort padding) the two schedulers
    are numerically identical under greedy decoding."""
    cfg, _, params = served
    mk = lambda: _reqs(cfg, lens=(10, 10), max_new=(3, 3), seed=2)
    cont = _continuous(cfg, params, 2).run(mk())
    coh = CohortScheduler(cfg, params, batch=2).run(mk())
    assert cont == coh


def test_continuous_slot_refill_under_arrivals(served):
    """More requests than slots + staggered arrivals: every request is
    served, outputs still match the solo oracle, and the arena drains."""
    cfg, _, params = served
    mk = lambda: [Request(uid=i, prompt=p.prompt, max_new=p.max_new,
                          arrival=float(i))
                  for i, p in enumerate(
                      _reqs(cfg, lens=(11, 6, 9, 7, 8), max_new=(2, 4, 3,
                                                                 2, 3),
                            seed=3))]
    sched = _continuous(cfg, params, 2)
    out = sched.run(mk())
    assert set(out) == set(range(5))
    oracle = {}
    for r in mk():
        r.arrival = 0.0
        oracle.update(_continuous(cfg, params, 1).run([r]))
    assert out == oracle
    assert sched.cache.used_blocks == 0
    assert sched.cache.free_blocks == sched.cache.n_blocks - 1
    snap = {row["name"]: row for row in sched.metrics.snapshot()}
    assert snap["serve.requests_total"]["value"] == 5
    assert snap["serve.tokens_total"]["value"] == 2 + 4 + 3 + 2 + 3


# ---------------------------------------------------------------------------
# Satellite fixes: recompile cap + prefill key split
# ---------------------------------------------------------------------------

def test_cohort_budget_bucketing_caps_recompiles(served):
    """Prompt lengths whose KV budgets land in the same power-of-two
    bucket must share one compiled (prefill, decode) pair."""
    cfg, _, params = served
    sched = CohortScheduler(cfg, params, batch=1, max_new=4)
    sched.run(_reqs(cfg, lens=(20,), max_new=(2,), seed=4))
    sched.run(_reqs(cfg, lens=(24,), max_new=(2,), seed=5))
    # budgets 25 and 29 both bucket to 32 -> one compiled pair
    assert len(sched._fns) == 1


def test_cohort_prefill_splits_sampling_key(served):
    """Regression: the prefill sample must consume a split of the loop
    key, not the key itself — a prefill-only run must advance the key."""
    cfg, _, params = served
    sched = CohortScheduler(cfg, params, batch=1, seed=7)
    key0 = np.asarray(sched.key).copy()
    sched.run(_reqs(cfg, lens=(8,), max_new=(1,), seed=6),
              temperature=1.0, max_steps=1)
    assert not np.array_equal(np.asarray(sched.key), key0)
    # and two consecutive prefill-only runs draw from different streams
    out1 = sched.run(_reqs(cfg, lens=(8,), max_new=(1,), seed=6),
                     temperature=1.0, max_steps=1)
    out2 = sched.run(_reqs(cfg, lens=(8,), max_new=(1,), seed=6),
                     temperature=1.0, max_steps=1)
    assert not np.array_equal(np.asarray(sched.key), key0)
    assert out1.keys() == out2.keys()


def test_continuous_sampling_is_scheduling_independent(served):
    """Per-request fold_in keys: sampled (temperature > 0) outputs don't
    depend on batch size or arrival order."""
    cfg, _, params = served
    mk = lambda: _reqs(cfg, lens=(9, 12, 7), max_new=(3, 3, 3), seed=8)
    a = ContinuousScheduler(cfg, params, batch=3, max_seq=64, block_len=8,
                            seed=11).run(mk(), temperature=0.7)
    b = ContinuousScheduler(cfg, params, batch=1, max_seq=64, block_len=8,
                            seed=11).run(mk()[::-1], temperature=0.7)
    assert a == b


# ---------------------------------------------------------------------------
# Arrival traces + launch wrapper + bench rows
# ---------------------------------------------------------------------------

def test_traces_edge_cases_deterministic():
    """rate=0, burst=1 and single-request traces are deterministic and
    (for rate=0) identical across arrival kinds and seeds."""
    for kind in ("uniform", "poisson", "bursty"):
        for seed in (0, 7):
            tr = make_trace(kind, 4, vocab=64, rate=0.0, seed=seed)
            assert [r.arrival for r in tr] == [0.0] * 4
    # rate=0 draws nothing from the RNG: prompts match the rate>0 trace
    a = make_trace("poisson", 4, vocab=64, rate=0.0, seed=3)
    b = make_trace("poisson", 4, vocab=64, rate=0.5, seed=3)
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    # burst=1 degenerates to poisson exactly (same draws, same gaps)
    p = make_trace("poisson", 6, vocab=64, rate=0.5, seed=5)
    b1 = make_trace("bursty", 6, vocab=64, rate=0.5, burst=1, seed=5)
    assert [r.arrival for r in p] == [r.arrival for r in b1]
    # single-request traces replay identically
    s1 = make_trace("bursty", 1, vocab=64, rate=0.5, burst=4, seed=9)
    s2 = make_trace("bursty", 1, vocab=64, rate=0.5, burst=4, seed=9)
    assert len(s1) == 1 and s1[0].arrival == s2[0].arrival
    assert np.array_equal(s1[0].prompt, s2[0].prompt)
    assert make_trace("uniform", 0, vocab=64) == []
    # invalid inputs fail loudly even when rate=0 would trivialize gaps
    with pytest.raises(ValueError):
        make_trace("laplace", 2, vocab=64, rate=0.0)
    with pytest.raises(ValueError):
        make_trace("bursty", 2, vocab=64, rate=0.0, burst=0)
    with pytest.raises(ValueError):
        make_trace("poisson", 2, vocab=64, rate=-1.0)
    with pytest.raises(ValueError):
        make_trace("uniform", -1, vocab=64)


def test_traces_shared_prefix_groups():
    plain = make_trace("uniform", 6, vocab=64, rate=0.5, seed=4)
    shared = make_trace("uniform", 6, vocab=64, rate=0.5, seed=4,
                        prefix_len=16, prefix_group=3)
    # every request in a group shares the same 16 leading tokens
    for g in (0, 1):
        heads = [shared[g * 3 + i].prompt[:16] for i in range(3)]
        assert all(np.array_equal(heads[0], h) for h in heads[1:])
    assert not np.array_equal(shared[0].prompt[:16], shared[3].prompt[:16])
    # tails and arrivals replay the prefix-free trace exactly (prefixes
    # are drawn after the prompts, so the RNG stream is unperturbed)
    for x, y in zip(plain, shared):
        assert np.array_equal(x.prompt, y.prompt[16:])
        assert x.arrival == y.arrival and x.max_new == y.max_new


def test_traces_deterministic_and_shaped():
    a = make_trace("poisson", 8, vocab=64, rate=0.5, seed=3)
    b = make_trace("poisson", 8, vocab=64, rate=0.5, seed=3)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[-1] > 0
    # same seed, different arrival process -> identical request shapes
    u = make_trace("uniform", 8, vocab=64, rate=0.5, seed=3)
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, u))
    bursty = make_trace("bursty", 8, vocab=64, rate=0.5, burst=4, seed=3)
    assert bursty[0].arrival == bursty[1].arrival    # burst members co-arrive
    with pytest.raises(ValueError):
        make_trace("laplace", 4, vocab=64)


def test_serving_loop_falls_back_to_cohort():
    cfg = ArchConfig(name="ssm", family="ssm", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=2, scheduler="continuous")
    assert loop.scheduler_kind == "cohort"
    out = loop.run(_reqs(cfg, lens=(8, 8), max_new=(2, 2)))
    assert all(len(v) == 2 for v in out.values())


def test_serve_scenarios_registered_and_runnable(served):
    from repro.bench.runner import RunOptions, project_scenario, sweep
    from repro.bench.scenario import ServeScenario, get_scenario, scenarios

    names = [s.name for s in scenarios(tag="serve")]
    for arrival in ("uniform", "poisson", "bursty"):
        for sched in ("continuous", "cohort"):
            assert f"serve/{arrival}/{sched}" in names
    # serving cells are excluded from the smoke kernel sweep
    assert not [s for s in scenarios(smoke=True) if s.is_serving]
    with pytest.raises(ValueError):
        project_scenario(get_scenario("serve/uniform/continuous"), "A100")

    sc = ServeScenario(
        name="serve/test/tiny", shape=(2, 3),
        workload={"scheduler": "continuous", "arrival": "uniform",
                  "n_requests": 3, "batch": 2, "rate": 1.0,
                  "prompt_lens": [5, 10], "max_new": [2, 3], "seed": 0,
                  "block_len": 8},
        tags=("serve",), section="serve")
    report = sweep([sc], chips=["A100"], opts=RunOptions(emit=None))
    rows = [r for r in report.results if r.scenario == "serve/test/tiny"]
    assert len(rows) == 1               # measured only: no projection rows
    m = rows[0].metrics
    assert rows[0].kind == "measured"
    assert m["us_median"] > 0 and len(m["times_us"]) >= 2
    assert m["tokens"] > 0 and m["requests"] == 3
    assert 0 < m["occupancy_mean"] <= 1
    assert m["tokens_per_s"] > 0


# ---------------------------------------------------------------------------
# Chunked prefill + prefix sharing
# ---------------------------------------------------------------------------

def _chunked(cfg, params, batch, *, chunk=16, prefix=False):
    return ContinuousScheduler(cfg, params, batch=batch, max_seq=64,
                               block_len=8, chunk_tokens=chunk,
                               prefix_cache=prefix)


def test_chunked_matches_chunked_solo_oracle_two_orders(served):
    """Chunked prefill must not change greedy outputs vs serving each
    request alone through the same chunked path, under both FIFO and
    reversed arrival orders, with and without prefix sharing."""
    cfg, _, params = served
    base = _reqs(cfg, lens=(21, 7, 12), max_new=(3, 4, 3), seed=12)

    def mk(order):
        arr = {i: float(j) for j, i in enumerate(order)}
        return [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new,
                        arrival=arr[r.uid]) for r in base]

    solo = _chunked(cfg, params, 1)
    oracle = {}
    for r in mk([0, 1, 2]):
        r.arrival = 0.0
        oracle.update(solo.run([r]))
    for prefix in (False, True):
        fifo = _chunked(cfg, params, 2, prefix=prefix).run(mk([0, 1, 2]))
        rev = _chunked(cfg, params, 2, prefix=prefix).run(mk([2, 1, 0]))
        assert fifo == oracle
        assert rev == oracle


def test_prefix_sharing_hits_and_is_bit_identical(served):
    """Requests sharing a 32-token prefix: the prefix cache must serve
    later prefills from shared blocks (hit_tokens > 0) without changing
    a single greedy token, and the arena must drain to free + evictable."""
    cfg, _, params = served
    rng = np.random.default_rng(13)
    head = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)

    def mk():
        return [Request(
            uid=i,
            prompt=np.concatenate(
                [head, rng2.integers(0, cfg.vocab, (t,)).astype(np.int32)]),
            max_new=3, arrival=float(i))
            for i, (rng2, t) in enumerate(
                (np.random.default_rng(20 + i), tail)
                for i, tail in enumerate((5, 9, 3, 7)))]

    plain = _chunked(cfg, params, 2)
    shared = _chunked(cfg, params, 2, prefix=True)
    out_plain = plain.run(mk())
    out_shared = shared.run(mk())
    assert out_shared == out_plain
    assert plain.cache.hit_tokens == 0
    assert shared.cache.hit_tokens > 0
    assert 0 < shared.cache.cache_hit_ratio < 1
    c = shared.cache
    assert c.used_blocks == 0 and c.reserved_blocks == 0
    assert c.free_blocks + c.evictable_blocks == c.n_blocks - 1


def test_chunked_jit_cache_bounded(served):
    """Ragged prompt lengths through chunked prefill compile at most one
    chunk fn per pow2 width <= chunk_tokens, independent of the trace."""
    cfg, _, params = served
    sched = _chunked(cfg, params, 2, chunk=16)
    lens = (3, 5, 9, 13, 16, 17, 21, 26, 31, 33)
    sched.run(_reqs(cfg, lens=lens, max_new=[2] * len(lens), seed=14))
    widths = set(sched._chunk_fns)
    assert widths <= {1, 2, 4, 8, 16}
    assert len(widths) <= 5
    # a second ragged run adds no new entries
    sched.run(_reqs(cfg, lens=(4, 11, 27), max_new=(2, 2, 2), seed=15))
    assert set(sched._chunk_fns) == widths


def test_chunked_sampling_is_scheduling_independent(served):
    """Temperature > 0 under chunked prefill + sharing still uses
    per-request keys: outputs are independent of batch and order."""
    cfg, _, params = served
    mk = lambda: _reqs(cfg, lens=(9, 18, 7), max_new=(3, 3, 3), seed=16)
    a = ContinuousScheduler(cfg, params, batch=3, max_seq=64, block_len=8,
                            chunk_tokens=8, seed=21).run(mk(),
                                                         temperature=0.7)
    b = ContinuousScheduler(cfg, params, batch=1, max_seq=64, block_len=8,
                            chunk_tokens=8, prefix_cache=True,
                            seed=21).run(mk()[::-1], temperature=0.7)
    assert a == b


def test_chunk_tokens_validation(served):
    cfg, _, params = served
    with pytest.raises(ValueError, match="multiple of block_len"):
        ContinuousScheduler(cfg, params, batch=1, max_seq=64, block_len=8,
                            chunk_tokens=12)
    with pytest.raises(ValueError, match="multiple of block_len"):
        ContinuousScheduler(cfg, params, batch=1, max_seq=64, block_len=8,
                            chunk_tokens=4)
    vlm_cfg = dataclasses.replace(cfg, n_patches=4)
    with pytest.raises(ValueError, match="vlm"):
        ContinuousScheduler(vlm_cfg, params, batch=1, max_seq=64,
                            block_len=8, chunk_tokens=16)
    # prefix_cache alone implies the finest legal chunk (block_len), so
    # short shared prefixes still land on a match boundary
    sched = ContinuousScheduler(cfg, params, batch=1, max_seq=64,
                                block_len=8, prefix_cache=True)
    assert sched.chunk_tokens == 8 and sched.prefix_cache


def test_serving_loop_auto_disables_chunking():
    """Schedulers without the chunked path (cohort fallback) silently
    drop the chunk/prefix flags instead of crashing."""
    cfg = ArchConfig(name="ssm", family="ssm", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=2, scheduler="continuous",
                       chunk_tokens=16, prefix_cache=True)
    assert loop.scheduler_kind == "cohort"
    assert loop.chunk_tokens is None and loop.prefix_cache is False
    out = loop.run(_reqs(cfg, lens=(8, 8), max_new=(2, 2)))
    assert all(len(v) == 2 for v in out.values())


def test_compare_gates_serving_metrics():
    """tokens_per_s (inverted, host-scaled) and cache_hit_ratio (absolute
    band) gate as synthetic scenario:metric rows."""
    from repro.bench.results import BenchReport, BenchResult
    from repro.obs.compare import compare_reports

    def row(tps, hit, us=1000.0):
        return BenchResult(
            scenario="serve/prefix/shared", kernel="serve", shape=[4, 16],
            dtype="bf16", strategy="continuous", chip="TPUv5e",
            metrics={"us_median": us, "times_us": [us] * 5,
                     "tokens_per_s": tps, "cache_hit_ratio": hit},
            kind="measured", section="serve")

    def rep(r):
        rep = BenchReport()
        rep.add(r)
        return rep

    base = rep(row(1000.0, 0.60))
    res = compare_reports(base, rep(row(700.0, 0.61)))
    by = {v.scenario: v for v in res.verdicts}
    assert by["serve/prefix/shared:tokens_per_s"].verdict == "regress"
    assert by["serve/prefix/shared:cache_hit_ratio"].verdict == "pass"
    res = compare_reports(base, rep(row(1300.0, 0.50)))
    by = {v.scenario: v for v in res.verdicts}
    assert by["serve/prefix/shared:tokens_per_s"].verdict == "improve"
    assert by["serve/prefix/shared:cache_hit_ratio"].verdict == "regress"
    assert res.n_regressions == 1
    # a uniformly slower host: us up 2x, tokens/s down 2x -> all pass
    res = compare_reports(base, rep(row(500.0, 0.60, us=2000.0)),
                          normalize=True)
    assert res.n_regressions == 0
    assert {v.verdict for v in res.verdicts} == {"pass"}
