"""End-to-end integration: training descends, checkpoint-resume is exact,
preemption saves restartable state, and the serving loop emits tokens that
match teacher forcing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import mesh as mesh_mod
from repro.checkpoint import Checkpointer
from repro.core.config import ArchConfig, AttnConfig, RunConfig
from repro.data import synth_batch
from repro.distributed.sharding import split_tree
from repro.launch.serve import Request, ServingLoop
from repro.launch.train import train_loop, build_train_step, set_param_axes
from repro.models import build_model
from repro.optim import adamw_init


def _cfg(vocab=64):
    return ArchConfig(name="it", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=vocab,
                      attn=AttnConfig(chunk=16))


def test_training_descends():
    run = RunConfig(lr=3e-3, warmup_steps=3, total_steps=40, zero1=False)
    _, _, history = train_loop(_cfg(), run, steps=40, batch=8, seq=32)
    first = float(np.mean(history[:5]))
    last = float(np.mean(history[-5:]))
    assert last < first - 0.2, (first, last)


def test_checkpoint_resume_is_exact(tmp_path):
    """Training N steps straight == training k, restarting, training N-k."""
    cfg = _cfg()
    run = RunConfig(lr=1e-3, warmup_steps=2, total_steps=20, zero1=False)

    p_straight, _, _ = train_loop(cfg, run, steps=10, batch=4, seq=32)

    d = str(tmp_path / "ck")
    train_loop(cfg, run, steps=6, batch=4, seq=32, ckpt_dir=d)
    p_resumed, _, _ = train_loop(cfg, run, steps=10, batch=4, seq=32,
                                 ckpt_dir=d, resume=True)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
        p_straight, p_resumed)


def test_grad_accumulation_matches_single_batch():
    """A=4 microbatches must produce (nearly) the same update as A=1."""
    cfg = _cfg()
    model = build_model(cfg)
    params, axes = split_tree(model.init(jax.random.PRNGKey(0)))
    set_param_axes(axes)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, batch=8, seq=32, seed=0, step=0).items()}
    outs = {}
    for a in (1, 4):
        run = RunConfig(microbatches=a, zero1=False, clip_norm=0.0,
                        warmup_steps=1, total_steps=10)
        step = jax.jit(build_train_step(model, run))
        opt = adamw_init(params)
        new_p, _, m = step(params, opt, batch, jnp.zeros((), jnp.int32))
        outs[a] = (new_p, float(m["ce"]), float(m["grad_norm"]))
    # loss and gradient norm agree (AdamW's step-1 sign amplification makes
    # raw param comparison meaningless at fp32 noise level)
    assert abs(outs[1][1] - outs[4][1]) < 5e-3
    assert abs(outs[1][2] - outs[4][2]) / outs[1][2] < 1e-2
    # update magnitudes agree in aggregate
    d1 = jnp.sqrt(sum(jnp.sum((a_ - b_) ** 2) for a_, b_ in zip(
        jax.tree.leaves(outs[1][0]), jax.tree.leaves(params))))
    d4 = jnp.sqrt(sum(jnp.sum((a_ - b_) ** 2) for a_, b_ in zip(
        jax.tree.leaves(outs[4][0]), jax.tree.leaves(params))))
    assert abs(float(d1) - float(d4)) / float(d1) < 0.05


def test_bf16_grad_compression_close_to_fp32():
    cfg = _cfg()
    model = build_model(cfg)
    params, axes = split_tree(model.init(jax.random.PRNGKey(0)))
    set_param_axes(axes)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, batch=8, seq=32, seed=0, step=0).items()}
    outs = {}
    for comp in ("none", "bf16"):
        run = RunConfig(microbatches=4, zero1=False, grad_compression=comp,
                        warmup_steps=1, total_steps=10)
        step = jax.jit(build_train_step(model, run))
        new_p, _, m = step(params, adamw_init(params), batch,
                           jnp.zeros((), jnp.int32))
        outs[comp] = float(m["ce"])
    assert abs(outs["none"] - outs["bf16"]) < 2e-2


def test_serving_loop_matches_greedy_teacher_forcing():
    cfg = _cfg(vocab=128)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(1)))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32)

    loop = ServingLoop(cfg, params, batch=2)
    reqs = [Request(uid=i, prompt=prompts[i], max_new=4) for i in range(2)]
    results = loop.run(reqs, temperature=0.0)

    # greedy reference: extend each prompt token by token via forward
    for i in range(2):
        toks = list(prompts[i])
        for _ in range(4):
            logits = model.forward(
                params, {"tokens": jnp.asarray([toks]),
                         "labels": jnp.zeros((1, len(toks)), jnp.int32)})
            nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
            toks.append(nxt)
        assert results[i] == toks[len(prompts[i]):], i


def test_serving_loop_handles_ragged_prompts():
    """Mixed prompt lengths must serve (the old np.stack path crashed),
    the longest (unpadded) member must be bit-exact vs a solo run, and
    every request must come back measured."""
    cfg = _cfg(vocab=128)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(1)))
    rng = np.random.default_rng(0)
    long_p = rng.integers(0, cfg.vocab, (16,)).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)

    loop = ServingLoop(cfg, params, batch=2)
    reqs = [Request(uid=0, prompt=long_p, max_new=4),
            Request(uid=1, prompt=short_p, max_new=4)]
    results = loop.run(reqs, temperature=0.0)
    assert set(results) == {0, 1}
    assert all(len(v) == 4 for v in results.values())

    # the unpadded member saw the identical computation a solo run sees
    solo = ServingLoop(cfg, params, batch=1)
    solo_out = solo.run([Request(uid=0, prompt=long_p, max_new=4)],
                        temperature=0.0)
    assert results[0] == solo_out[0]

    # per-request observability: TTFT/total filled in, metrics recorded
    for r in reqs:
        assert r.ttft_ms is not None and r.total_ms >= r.ttft_ms > 0
    snap = {row["name"]: row for row in loop.metrics.snapshot()}
    assert snap["serve.requests_total"]["value"] == 2
    assert snap["serve.tokens_total"]["value"] == 8
    assert snap["serve.ttft_ms"]["count"] == 2
    assert snap["serve.decode_ms"]["count"] >= 3
    assert snap["serve.batch_occupancy"]["mean"] == 1.0
    assert snap["serve.queue_depth"]["value"] == 0


def test_pack_prompts_left_pads_and_masks():
    from repro.launch.serve import mask_padded_cache, pack_prompts
    reqs = [Request(uid=0, prompt=np.arange(1, 6, dtype=np.int32),
                    max_new=1),
            Request(uid=1, prompt=np.arange(1, 3, dtype=np.int32),
                    max_new=1)]
    tokens, pads = pack_prompts(reqs, batch=3)
    assert tokens.shape == (3, 5)
    assert list(pads) == [0, 3, 0]          # empty slot 2 stays all-pad
    np.testing.assert_array_equal(tokens[0], [1, 2, 3, 4, 5])
    np.testing.assert_array_equal(tokens[1], [0, 0, 0, 1, 2])
    # every sequence's last prompt token lands in the final column — the
    # position prefill samples from
    assert tokens[0, -1] == 5 and tokens[1, -1] == 2

    class State:                             # minimal kpos carrier
        def __init__(self, kpos):
            self.kpos = kpos

        def _replace(self, kpos):
            return State(kpos)

    kpos = jnp.broadcast_to(jnp.arange(5, dtype=jnp.int32), (1, 3, 5))
    masked = mask_padded_cache(State(kpos), pads).kpos
    np.testing.assert_array_equal(masked[0, 0], [0, 1, 2, 3, 4])
    np.testing.assert_array_equal(masked[0, 1], [-1, -1, -1, 3, 4])
    # zero pads: the state object passes through untouched
    state = State(kpos)
    assert mask_padded_cache(state, np.zeros((3,), np.int32)) is state


def test_elastic_restore_across_logical_meshes(tmp_path):
    """Save unsharded, restore under explicit (new-mesh) shardings, and keep
    training — the elastic-scaling path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = _cfg()
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"params": params})

    mesh = mesh_mod.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), {"params": params})
    restored = ck.restore({"params": params}, shardings=sh)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, batch=2, seq=16, seed=0, step=5).items()}
    loss, _ = model.loss(restored["params"], batch)
    assert bool(jnp.isfinite(loss))
