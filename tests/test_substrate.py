"""Substrate tests: balance model vs the paper's numbers, optimizer,
checkpointing (atomic/async/elastic), data determinism, fault tolerance."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import mesh as mesh_mod
from repro.checkpoint import Checkpointer
from repro.core import balance, hardware
from repro.core.config import ArchConfig, AttnConfig, RunConfig
from repro.data import Prefetcher, synth_batch
from repro.distributed.fault_tolerance import (PreemptionGuard, StepStats,
                                               run_with_retries)
from repro.optim import adamw_init, adamw_update, lr_schedule


# ---------------------------------------------------------------------------
# Machine balance — validated against the paper's own derived numbers (§6)
# ---------------------------------------------------------------------------

def test_expected_speedup_matches_paper():
    v100 = hardware.get_chip("V100")
    a100 = hardware.get_chip("A100")
    # paper: FLOP ratio 1.38x, BW ratio 1.73x, T_speedup = 1.38x
    assert abs(a100.tflops_f32 / v100.tflops_f32 - 1.38) < 0.01
    assert abs(a100.mem_bw_gbs / v100.mem_bw_gbs - 1.73) < 0.01
    assert abs(balance.expected_speedup(v100, a100) - 1.38) < 0.01


def test_bf_ratios_in_paper_ranges():
    # paper: Tesla-class 0.03-0.07 B/F fp32, 0.12-0.17 fp64 (K80's 0.175
    # rounds into the paper's 0.17); RTX-2060's fp64 B/F = 2.0
    for name in ("K80", "P100", "V100", "A100"):
        b = balance.machine_balance(hardware.get_chip(name))
        assert 0.03 <= b.bf_f32 <= 0.08, name
        assert 0.11 <= b.bf_f64 <= 0.18, name
    rtx = balance.machine_balance(hardware.get_chip("RTX2060S"))
    assert abs(rtx.bf_f64 - 2.0) < 0.01


def test_speedup_min_property():
    # T_speedup is the min of the two ratios for every pair
    chips = [hardware.get_chip(n) for n in ("K80", "P100", "V100", "A100")]
    for old in chips:
        for new in chips:
            t = balance.expected_speedup(old, new)
            assert t <= new.tflops_f32 / old.tflops_f32 + 1e-9
            assert t <= new.mem_bw_gbs / old.mem_bw_gbs + 1e-9


def test_roofline_attainable():
    chip = hardware.get_chip("A100")
    ridge = balance.ridge_point(chip)
    lo = balance.attainable_flops(ridge / 10, chip)
    hi = balance.attainable_flops(ridge * 10, chip)
    assert lo < hi
    assert hi == pytest.approx(chip.tflops_f32 * 1e12)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=0.05,
                                      weight_decay=0.0, clip_norm=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_clipping():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = adamw_update(g, opt, params, lr=0.1, clip_norm=1.0)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)


def test_lr_schedule_shape():
    lrs = [float(lr_schedule(jnp.asarray(s), lr=1.0, warmup=10, total=100))
           for s in range(1, 101)]
    assert lrs[0] < lrs[8] <= 1.0          # warmup rises
    assert max(lrs) <= 1.0
    assert lrs[-1] < lrs[20]               # cosine decays
    assert lrs[-1] >= 0.099                # min ratio floor


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"m": jnp.ones((3,))}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(7, tree)
    assert ck.latest_step() == 7
    restored = ck.restore(tree)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), tree,
                 restored)


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=True)
    for step in (1, 2, 3):
        ck.save(step, _tree(step))
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000002", "step_00000003"]
    r = ck.restore(_tree())
    np.testing.assert_allclose(r["params"]["w"], _tree(3)["params"]["w"])


def test_checkpoint_latest_is_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))
    # a torn/partial later save must not corrupt LATEST
    os.makedirs(tmp_path / "step_00000009.tmp", exist_ok=True)
    assert ck.latest_step() == 1
    ck.restore(_tree())  # still restorable


def test_checkpoint_elastic_restore_targets_sharding(tmp_path):
    """Restore places arrays under explicitly-given (new-mesh) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = mesh_mod.make_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(1, tree)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored = ck.restore(tree, shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_checkpoint_missing_key_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        ck.restore({"a": jnp.zeros(3), "b": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def _cfg():
    return ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=97,
                      attn=AttnConfig(chunk=8))


def test_synth_batch_deterministic_and_shifted():
    cfg = _cfg()
    b1 = synth_batch(cfg, batch=4, seq=16, seed=3, step=11)
    b2 = synth_batch(cfg, batch=4, seq=16, seed=3, step=11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synth_batch(cfg, batch=4, seq=16, seed=3, step=12)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token-shifted with a masked tail
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()
    assert b1["tokens"].max() < cfg.vocab


def test_prefetcher_replays_from_step():
    cfg = _cfg()
    pf = Prefetcher(cfg, batch=2, seq=8, seed=5, start_step=3)
    try:
        first = next(iter(pf))
    finally:
        pf.close()
    want = synth_batch(cfg, batch=2, seq=8, seed=5, step=3)
    np.testing.assert_array_equal(np.asarray(first["tokens"]),
                                  want["tokens"])


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_run_with_retries_transient():
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert run_with_retries(flaky, backoff=0.001) == "ok"
    assert len(attempts) == 3


def test_run_with_retries_exhausts():
    def always_fails():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        run_with_retries(always_fails, max_retries=2, backoff=0.001)


def test_straggler_detection():
    stats = StepStats()
    for step in range(10):
        stats.record(step, 0.1)
    assert stats.record(10, 1.0, factor=3.0) is True
    assert stats.straggler_events == [10]
    assert stats.record(11, 0.1) is False


def test_preemption_guard_flag():
    with PreemptionGuard() as g:
        assert g.requested is False
        g._handler(15, None)
        assert g.requested is True
