"""Autotuning subsystem tests: registry round-trip + schema versioning,
analytic pruning correctness, and an end-to-end tune-then-lookup on the
stream kernel (Pallas interpret mode)."""
import json
import os

import pytest

from repro.core import hardware
from repro.core.async_pipeline import Strategy
from repro.kernels import ops
from repro.tuning import (Autotuner, Measurement, Registry, SchemaMismatch,
                          SearchSpace, TuningRecord, SCHEMA_VERSION,
                          default_task, make_key, predict_time, tuned)
from repro.tuning.autotuner import decode_config


def _record(kernel="stream", shape=(64, 128)):
    return TuningRecord(
        kernel=kernel, shape=list(shape), dtype="float32", chip="TPUv5e",
        best={"strategy": "overlap", "tile_rows": 8, "n_tiles": 4,
              "depth": 2},
        best_us=12.5, default_us=20.0, speedup_vs_default=1.6,
        measurements=[Measurement(
            config={"strategy": "overlap", "tile_rows": 8, "n_tiles": 4,
                    "depth": 2},
            us_median=12.5, us_mean=13.0, us_min=12.0, us_std=0.5,
            n_trials=5, predicted_us=10.0)],
        n_candidates=1, n_pruned=0)


# --- registry ---------------------------------------------------------------

def test_registry_round_trip(tmp_path):
    path = str(tmp_path / "reg.json")
    reg = Registry(path)
    rec = _record()
    reg.put(rec)
    # fresh object re-reads from disk
    reg2 = Registry(path)
    got = reg2.get("stream", (64, 128), "float32", "TPUv5e")
    assert got is not None
    assert got.key == rec.key == make_key("stream", (64, 128), "float32",
                                          "TPUv5e")
    assert got.best == rec.best
    assert got.best_us == rec.best_us
    assert len(got.measurements) == 1
    assert got.measurements[0].us_median == 12.5
    assert got.measurements[0].error is None
    # miss on any key component
    assert reg2.get("stream", (64, 129), "float32", "TPUv5e") is None
    assert reg2.get("stream", (64, 128), "bfloat16", "TPUv5e") is None


def test_registry_schema_mismatch_ignored_and_strict(tmp_path):
    path = str(tmp_path / "reg.json")
    with open(path, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION + 999,
                   "records": {"stream|64x128|float32|TPUv5e": {"junk": 1}}},
                  f)
    # default: stale cache is ignored, not misread
    reg = Registry(path)
    assert len(reg) == 0
    assert reg.get("stream", (64, 128), "float32", "TPUv5e") is None
    # strict: surfaced
    with pytest.raises(SchemaMismatch):
        Registry(path, strict=True).load()
    # saving rewrites the current schema
    reg.put(_record())
    assert json.load(open(path))["schema_version"] == SCHEMA_VERSION


def test_registry_concurrent_saves_merge(tmp_path):
    """Two tuner processes writing different cells must not lose updates:
    save() re-merges the file so the last writer keeps the other's keys."""
    path = str(tmp_path / "reg.json")
    a, b = Registry(path), Registry(path)
    a.load(), b.load()                  # both snapshot the (empty) file
    a.put(_record(kernel="stream"))
    b.put(_record(kernel="matmul"))     # stale view, saved second
    fresh = Registry(path)
    assert {r.kernel for r in fresh.records()} == {"stream", "matmul"}


def test_registry_save_does_not_revert_unwritten_keys(tmp_path):
    """Only keys THIS process wrote overlay the disk view: a merely-read
    record must not be rolled back over another writer's newer version."""
    path = str(tmp_path / "reg.json")
    Registry(path).put(_record(kernel="stream"))        # v1 on disk
    a = Registry(path)
    a.load()                            # A snapshots stream@v1
    b = Registry(path)
    newer = _record(kernel="stream")
    newer.best_us = 1.0                 # B force-re-tunes stream -> v2
    b.put(newer)
    a.put(_record(kernel="matmul"))     # A writes a different cell
    fresh = Registry(path)
    stream = fresh.get("stream", (64, 128), "float32", "TPUv5e")
    assert stream.best_us == 1.0        # B's v2 survived A's stale save
    assert fresh.get("matmul", (64, 128), "float32", "TPUv5e") is not None


def test_interpret_mode_is_part_of_registry_key(tmp_path):
    """Interpret and compiled tunes of the same cell coexist (v2 keys)."""
    reg = Registry(str(tmp_path / "reg.json"))
    cpu = _record()
    tpu = _record()
    tpu.interpret = False
    tpu.best_us = 1.0
    reg.put(cpu)
    reg.put(tpu)
    assert len(reg) == 2
    assert reg.get("stream", (64, 128), "float32", "TPUv5e",
                   interpret=True).best_us == 12.5
    assert reg.get("stream", (64, 128), "float32", "TPUv5e",
                   interpret=False).best_us == 1.0


def test_registry_corrupt_file_treated_as_empty(tmp_path):
    path = str(tmp_path / "reg.json")
    with open(path, "w") as f:
        f.write("{not json")
    assert len(Registry(path)) == 0


# --- search space / pruning -------------------------------------------------

def test_search_space_candidates_feasible():
    space = SearchSpace("stream", (512, 256))
    cands = space.candidates()
    assert len(cands) > 10
    for c in cands:
        # every enumerated candidate divides the problem
        assert 512 % (c.config["tile_rows"] * c.config["n_tiles"]) == 0
        assert c.predicted_us > 0
        assert c.vmem_bytes > 0


def test_pruning_drops_vmem_infeasible():
    # a tiny VMEM budget makes every multi-buffered candidate infeasible
    space = SearchSpace("stream", (512, 256), vmem_limit=1)
    survivors, dropped = space.pruned()
    assert not survivors
    assert all("vmem" in c.why_pruned for c in dropped)


def test_pruning_drops_analytically_dominated():
    space = SearchSpace("stream", (512, 256))
    survivors, dropped = space.pruned(keep_ratio=1.5)
    assert survivors and dropped
    best = min(c.predicted_us for c in survivors)
    # survivors all within the ratio; every dominance-drop is outside it
    # (vmem and break-even drops are the other two, non-ratio prune classes)
    for c in survivors:
        assert c.predicted_us <= 1.5 * best * (1 + 1e-9)
    for c in dropped:
        if "vmem" not in c.why_pruned and "break-even" not in c.why_pruned:
            assert c.predicted_us > 1.5 * best
    # SYNC is strictly dominated by REGISTER_BYPASS in the model
    # (staging re-pass: 1.5*t_m vs t_m), so a tight ratio always drops it
    tight, _ = space.pruned(keep_ratio=1.01)
    assert tight
    assert all(c.config["strategy"] != Strategy.SYNC for c in tight)


def test_pruning_drops_past_break_even_depths():
    """A ring whose issue-ahead covers the whole tile stream spends the
    entire memory time in fill — analytically infeasible, pruned before
    measurement.  stream (512,256) enumerates n_tiles=2 cells where depth 3+
    (issue-ahead >= 2) crosses that bound."""
    space = SearchSpace("stream", (512, 256))
    survivors, dropped = space.pruned()
    be = [c for c in dropped if "break-even" in c.why_pruned]
    assert be, "expected at least one analytically infeasible depth pruned"
    from repro.tuning import issue_ahead
    for c in be:
        ahead = issue_ahead(c.config["depth"], c.config.get("wait_group"))
        assert ahead >= c.config["n_tiles"]
    # and no surviving async candidate is past its break-even point
    for c in survivors:
        if c.config["strategy"] in (Strategy.OVERLAP, Strategy.DROP_OFF):
            ahead = issue_ahead(c.config["depth"], c.config.get("wait_group"))
            assert ahead < c.config["n_tiles"]


def test_search_space_covers_depth_and_wait_group_axes():
    """The tentpole axes are actually enumerated: ring depths {2,3,4} and,
    at depth > 2, both the deepest wait group (None) and the shallow one."""
    from repro.tuning import strategy_depth_waits
    shapes = {s for s in strategy_depth_waits(Strategy.OVERLAP)}
    assert {d for d, _ in shapes} == {2, 3, 4}
    assert (3, 1) in shapes and (4, 1) in shapes and (4, None) in shapes
    assert strategy_depth_waits(Strategy.SYNC) == ((2, None),)
    cands = SearchSpace("stream", (512, 256)).candidates()
    seen = {(c.config["depth"], c.config["wait_group"]) for c in cands
            if c.config["strategy"] == Strategy.OVERLAP}
    assert seen == set(shapes)
    # wait_group changes the prediction at depth 4 (bandwidth vs fill)
    deep = predict_time(Strategy.OVERLAP, 1.0, 1e9, depth=4, n_tiles=64)
    shallow = predict_time(Strategy.OVERLAP, 1.0, 1e9, depth=4, n_tiles=64,
                           wait_group=1)
    assert deep != shallow


def test_tma_search_space_has_no_wait_group_axis():
    """TMA's mbarrier completion has no partial-wait analogue, so the
    enumeration carries only the depth axis — and the autotuner codec
    round-trips the new strategy name."""
    from repro.tuning import strategy_depth_waits
    assert strategy_depth_waits(Strategy.TMA) == ((2, None), (3, None),
                                                  (4, None))
    cands = SearchSpace("stream", (512, 256)).candidates()
    tma = [c for c in cands if c.config["strategy"] is Strategy.TMA]
    assert tma, "TMA candidates must be enumerated"
    assert {c.config["wait_group"] for c in tma} == {None}
    assert {c.config["depth"] for c in tma} == {2, 3, 4}
    cfg = decode_config({"strategy": "tma", "depth": 3, "tile_rows": 8,
                         "n_tiles": 4})
    assert cfg["strategy"] is Strategy.TMA


def test_tma_predict_time_amortizes_latency_with_depth():
    """The TMA cost term behaves like the papers describe: a deeper ring
    recovers bulk bandwidth (hiding the higher per-transaction latency),
    and per-tile issue cost is cheaper than the cp.async-style loop."""
    nbytes, n = 2.1e8, 64             # ~4us tiles: TMA's sweet spot
    flops = 0.1 * (nbytes / 819e9) * 197e12          # memory-bound
    t2 = predict_time(Strategy.TMA, flops, nbytes, depth=2, n_tiles=n)
    t4 = predict_time(Strategy.TMA, flops, nbytes, depth=4, n_tiles=n)
    assert t4 < t2                     # deeper ring covers TMA_LATENCY_S
    # wait_group must not perturb the TMA prediction (no such axis)
    assert predict_time(Strategy.TMA, flops, nbytes, depth=4, n_tiles=n,
                        wait_group=1) == t4
    # where per-copy issue overhead dominates, the single-descriptor bulk
    # path beats the cp.async-style overlap loop...
    t_overlap = predict_time(Strategy.OVERLAP, flops, nbytes, depth=4,
                             n_tiles=n)
    assert t4 < t_overlap
    # ...but at large tiles the 7% bulk-bandwidth cap hands overlap the win
    # (the regime split the Hopper papers report)
    big = 1e9
    assert predict_time(Strategy.OVERLAP, 0.0, big, depth=4, n_tiles=8) < \
        predict_time(Strategy.TMA, 0.0, big, depth=4, n_tiles=8)


def test_predict_time_strategy_ordering():
    """Mixed regime (t_c ~ t_m/2): overlap hides the compute under the DMA
    and wins; sync pays the staging re-pass and loses — paper Fig 3a."""
    nbytes = 1e9
    flops = 0.5 * (nbytes / 819e9) * 197e12     # t_c = t_m / 2
    t = {s: predict_time(s, flops, nbytes, depth=2, n_tiles=64)
         for s in Strategy}
    assert t[Strategy.OVERLAP] < t[Strategy.REGISTER_BYPASS]
    assert t[Strategy.REGISTER_BYPASS] < t[Strategy.SYNC]
    # and at near-zero compute the ring fill makes overlap lose to bypass
    t0 = {s: predict_time(s, 1.0, nbytes, depth=2, n_tiles=64)
          for s in Strategy}
    assert t0[Strategy.REGISTER_BYPASS] < t0[Strategy.OVERLAP]


# --- end-to-end: tune, cache-hit, lookup ------------------------------------

@pytest.fixture
def fresh_defaults():
    yield
    ops.reset_default_configs()


def test_tune_then_lookup_stream(tmp_path, fresh_defaults):
    reg = Registry(str(tmp_path / "reg.json"))
    tuner = Autotuner(reg, warmup=1, repeats=2)
    task = default_task("stream", shape=(64, 128))
    rec = tuner.tune(task)
    assert rec.best_us > 0
    assert rec.n_candidates > 0
    # the hard-coded default was measured, so the speedup is well-defined
    assert rec.default_us > 0
    assert rec.speedup_vs_default >= 1.0
    # winner is the measured minimum
    ok = [m for m in rec.measurements if m.error is None]
    assert rec.best_us == min(m.us_median for m in ok)

    # second tune of the same cell is a cache hit: no re-measurement
    measured = len(rec.measurements)
    rec2 = tuner.tune(task)
    assert rec2.best == rec.best and len(rec2.measurements) == measured
    mtime = os.path.getmtime(reg.path)
    tuner.tune(task)
    assert os.path.getmtime(reg.path) == mtime       # not rewritten

    # tuned() lookup returns the decoded winner, ready to splat into ops
    cfg = tuned("stream", (64, 128), registry=reg)
    assert isinstance(cfg["strategy"], Strategy)
    assert cfg == decode_config(rec.best)
    out = ops.stream(jax_uniform((64, 128)), iters=2, **cfg)
    assert out.shape == (64, 128)

    # lookup miss falls back to the kernel's default config
    miss = tuned("stream", (128, 128), registry=reg)
    assert miss == ops.default_config("stream")
    assert tuned("stream", (128, 128), registry=reg,
                 fallback_to_default=False) is None


def test_cache_miss_on_interpret_mode_mismatch(tmp_path):
    """A compiled-mode record must not satisfy an interpreter-mode tune
    (or vice versa): the timings are not comparable across modes."""
    reg = Registry(str(tmp_path / "reg.json"))
    stale = _record(shape=(64, 128))
    stale.interpret = False              # pretend it was tuned compiled
    stale.best_us = 0.001                # obviously not a CPU timing
    reg.put(stale)
    tuner = Autotuner(reg, warmup=1, repeats=1)
    rec = tuner.tune(default_task("stream", shape=(64, 128)))
    assert rec.interpret is True         # re-measured in this process's mode
    assert rec.best_us > 0.001
    # and the interpret-mode record now satisfies interpret-mode tunes
    again = tuner.tune(default_task("stream", shape=(64, 128)))
    assert again.created_at == rec.created_at      # cache hit, no re-measure


def test_apply_registry_defaults_installs_winner(tmp_path, fresh_defaults):
    from repro.tuning import apply_registry_defaults
    reg = Registry(str(tmp_path / "reg.json"))
    rec = _record(shape=(64, 128))
    rec.best = {"strategy": "drop_off", "tile_rows": 16, "n_tiles": 2,
                "depth": 4}
    rec.chip = hardware.TARGET.name
    reg.put(rec)
    applied = apply_registry_defaults(reg)
    assert "stream" in applied
    cfg = ops.default_config("stream")
    assert cfg["strategy"] == Strategy.DROP_OFF
    assert cfg["tile_rows"] == 16 and cfg["depth"] == 4
    # unknown keys from a stale registry are rejected, not injected
    with pytest.raises(KeyError):
        ops.set_default_config("stream", bogus=1)


def test_tuned_default_invalid_for_shape_falls_back_to_seed(fresh_defaults):
    """A winner tuned at a large shape must not crash smaller calls: the
    wrapper degrades to the seed constants when the installed tile does not
    divide the problem."""
    ops.set_default_config("stream", tile_rows=32, n_tiles=8)   # block=256
    x = jax_uniform((64, 128))                                  # rows=64
    out = ops.stream(x, iters=1)        # would raise without the fallback
    assert out.shape == (64, 128)
    # explicit bad arguments still raise (user error is not masked)
    with pytest.raises(ValueError):
        ops.stream(x, iters=1, tile_rows=32, n_tiles=8)


def test_tuned_lud_block_size_falls_back_to_seed(fresh_defaults):
    """lud validates bs with ValueError too, so the same degradation holds
    for a tuned block size that does not divide a smaller matrix."""
    import jax.numpy as jnp
    ops.set_default_config("lud", bs=64)
    a = jax_uniform((96, 96)) + 96 * jnp.eye(96)     # 96 % 64 != 0
    out = ops.lud(a)                    # degrades to seed bs=32
    assert out.shape == (96, 96)
    with pytest.raises(ValueError):
        ops.lud(a, bs=64)               # explicit user error still raises


def jax_uniform(shape):
    import jax
    import jax.numpy as jnp
    return jax.random.uniform(jax.random.PRNGKey(0), shape, jnp.float32)
