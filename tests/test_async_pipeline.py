"""Loop-emitter edge regimes (interpret mode): every strategy through a
minimal streaming kernel at ring depths beyond double-buffering, degenerate
tile counts (``n_tiles < depth``, ``n_tiles == 0``), traced ``n_tiles``, and
explicit wait-group depths — all validated element-exactly against the
closed-form expectation.  Plus the PipelineSpec / parse_strategy /
scratch_for unit surface.

The harness input is sized to exactly ``n_tiles`` tiles, so any emitter that
issues a copy past the stream's end with a *static* index fails Pallas's
slice validation at trace time — the tests would error, not just miscompare.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.async_pipeline import (ALL_STRATEGIES, PipelineSpec, Strategy,
                                       TileStream, WriteBack, as_spec,
                                       compiler_params, emit, parse_strategy,
                                       scratch_for, writeback_scratch)

TILE_ROWS, WIDTH = 4, 128


# --- parse_strategy ---------------------------------------------------------

def test_parse_strategy_case_insensitive_and_passthrough():
    assert parse_strategy("overlap") is Strategy.OVERLAP
    assert parse_strategy("OVERLAP") is Strategy.OVERLAP
    assert parse_strategy("  Drop_Off ") is Strategy.DROP_OFF
    for s in ALL_STRATEGIES:
        assert parse_strategy(s) is s
        assert parse_strategy(s.value.upper()) is s


def test_parse_strategy_error_lists_valid_names():
    with pytest.raises(ValueError) as ei:
        parse_strategy("cp_async")
    msg = str(ei.value)
    assert "cp_async" in msg
    for s in ALL_STRATEGIES:
        assert s.value in msg


# --- PipelineSpec -----------------------------------------------------------

def test_pipeline_spec_validation_and_hashability():
    for bad in (dict(depth=0), dict(wait_group=-1), dict(out_depth=0)):
        with pytest.raises(ValueError):
            PipelineSpec(**bad)
    # frozen + hashable: must travel through jit static args
    assert hash(PipelineSpec()) == hash(PipelineSpec())
    assert PipelineSpec(depth=3) != PipelineSpec(depth=4)
    # strategy names are parsed wherever a spec is built
    assert PipelineSpec(strategy="Sync").strategy is Strategy.SYNC
    with pytest.raises(ValueError):
        PipelineSpec(strategy="cp_async")


def test_pipeline_spec_ring_depth_and_ahead():
    assert PipelineSpec(strategy=Strategy.SYNC, depth=4).ring_depth == 1
    assert PipelineSpec(strategy=Strategy.SYNC, depth=4).ahead == 0
    assert PipelineSpec(strategy=Strategy.OVERLAP, depth=4).ring_depth == 4
    assert PipelineSpec(strategy=Strategy.OVERLAP, depth=4).ahead == 3
    # wait_group caps (and is clamped to) the safe issue-ahead
    assert PipelineSpec(strategy=Strategy.OVERLAP, depth=4,
                        wait_group=1).ahead == 1
    assert PipelineSpec(strategy=Strategy.OVERLAP, depth=3,
                        wait_group=9).ahead == 2
    assert PipelineSpec(strategy=Strategy.DROP_OFF, depth=3,
                        wait_group=0).ahead == 0
    # async depth=1 still allocates a legal 2-slot ring
    assert PipelineSpec(strategy=Strategy.OVERLAP, depth=1).ring_depth == 2


def test_pipeline_spec_from_config_ignores_unrelated_keys():
    spec = PipelineSpec.from_config(
        {"strategy": "drop_off", "depth": 3, "wait_group": 1,
         "out_depth": 3, "tile_rows": 8, "n_tiles": 4})
    assert spec == PipelineSpec(strategy=Strategy.DROP_OFF, depth=3,
                                wait_group=1, out_depth=3)
    assert PipelineSpec.from_config({}).strategy is Strategy.OVERLAP


def test_scratch_for_staging_only_for_sync():
    """SYNC gets a full-tile staging buffer (the register-round-trip model);
    async strategies get a 1-element placeholder so scratch arity is fixed."""
    tile = (8, 128)
    _, _, stage = scratch_for(Strategy.SYNC, tile, jnp.float32)
    assert stage.shape == tile
    for s in (Strategy.REGISTER_BYPASS, Strategy.OVERLAP, Strategy.DROP_OFF,
              Strategy.TMA):
        ring, sems, stage = scratch_for(
            PipelineSpec(strategy=s, depth=3), tile, jnp.float32)
        assert stage.shape == (1, 1)
        expect = 1 if s is Strategy.REGISTER_BYPASS else 3
        assert ring.shape == (expect, *tile)


def test_tma_ahead_ignores_wait_group():
    """TMA's mbarrier tracks every outstanding byte of its slot, so the
    wait-group axis collapses: issue-ahead is always depth - 1."""
    assert PipelineSpec(strategy=Strategy.TMA, depth=4).ahead == 3
    assert PipelineSpec(strategy=Strategy.TMA, depth=4, wait_group=1).ahead \
        == 3
    assert PipelineSpec(strategy=Strategy.TMA, depth=3, wait_group=0).ahead \
        == 2
    assert PipelineSpec(strategy=Strategy.TMA, depth=4).ring_depth == 4


# --- the streaming harness --------------------------------------------------

def _body(x_hbm, o_hbm, in_buf, out_buf, stage, in_sems, out_sems, *,
          spec, n_tiles):
    idx = lambda i: (pl.ds(i * TILE_ROWS, TILE_ROWS), slice(None))
    stream = TileStream(hbm=x_hbm, vmem=in_buf, sem=in_sems, index=idx,
                        depth=spec.ring_depth)
    wb = WriteBack(hbm=o_hbm, vmem=out_buf, sem=out_sems, index=idx,
                   depth=spec.out_depth)
    if spec.strategy == Strategy.DROP_OFF:
        emit(spec, [stream], n_tiles,
             lambda i, vals: wb.push(i, vals[0] * 2.0 + 1.0))
    else:
        emit(spec, [stream], n_tiles,
             lambda i, bufs: wb.push(i, bufs[0][...] * 2.0 + 1.0),
             staging=[stage])
    wb.drain(n_tiles)


def _static_kernel(x_hbm, o_hbm, *scratch, spec, n_tiles):
    _body(x_hbm, o_hbm, *scratch, spec=spec, n_tiles=n_tiles)


def _traced_kernel(n_ref, x_hbm, o_hbm, *scratch, spec):
    _body(x_hbm, o_hbm, *scratch, spec=spec, n_tiles=n_ref[0])


def run_pipeline(spec, n_tiles, *, traced=False):
    """Stream ``n_tiles`` tiles of 2x+1 through emit()+WriteBack; the output
    aliases the input so untouched rows must come back unchanged."""
    spec = as_spec(spec)
    rows = max(n_tiles, 1) * TILE_ROWS
    x = (jnp.arange(rows * WIDTH, dtype=jnp.float32)
         .reshape(rows, WIDTH)) / 128.0
    in_buf, in_sems, stage = scratch_for(spec, (TILE_ROWS, WIDTH), x.dtype)
    out_buf, out_sems = writeback_scratch(spec, (TILE_ROWS, WIDTH), x.dtype)
    if traced:
        kernel = functools.partial(_traced_kernel, spec=spec)
        args = (jnp.array([n_tiles], jnp.int32), x)
        in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),
                    pl.BlockSpec(memory_space=pl.ANY)]
        aliases = {1: 0}
    else:
        kernel = functools.partial(_static_kernel, spec=spec,
                                   n_tiles=n_tiles)
        args = (x,)
        in_specs = [pl.BlockSpec(memory_space=pl.ANY)]
        aliases = {0: 0}
    out = pl.pallas_call(
        kernel, grid=(1,),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=in_specs, out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[in_buf, out_buf, stage, in_sems, out_sems],
        input_output_aliases=aliases, interpret=True,
        compiler_params=compiler_params(dimension_semantics=("arbitrary",)),
    )(*args)
    want = np.asarray(x).copy()
    done = n_tiles * TILE_ROWS
    want[:done] = want[:done] * 2.0 + 1.0
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


# --- edge regimes -----------------------------------------------------------

@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("n_tiles", [0, 2])
def test_every_strategy_handles_empty_and_short_streams(strategy, n_tiles):
    run_pipeline(PipelineSpec(strategy=strategy, depth=3), n_tiles)


@pytest.mark.parametrize("strategy", [Strategy.OVERLAP, Strategy.DROP_OFF,
                                      Strategy.TMA])
@pytest.mark.parametrize("n_tiles", [1, 3])
def test_async_n_tiles_at_or_below_depth(strategy, n_tiles):
    """n_tiles <= depth: the warm-up must not issue (or even trace) a copy
    past the end of the stream."""
    run_pipeline(PipelineSpec(strategy=strategy, depth=3), n_tiles)
    run_pipeline(PipelineSpec(strategy=strategy, depth=5), n_tiles)


@pytest.mark.parametrize("strategy", [Strategy.OVERLAP, Strategy.DROP_OFF])
@pytest.mark.parametrize("depth,wait_group", [(4, None), (4, 1), (5, 2)])
def test_deep_rings_with_wait_groups(strategy, depth, wait_group):
    run_pipeline(PipelineSpec(strategy=strategy, depth=depth,
                              wait_group=wait_group, out_depth=3), 8)


@pytest.mark.parametrize("strategy", [Strategy.OVERLAP, Strategy.DROP_OFF])
def test_wait_group_zero_degenerates_to_no_overlap(strategy):
    run_pipeline(PipelineSpec(strategy=strategy, depth=3, wait_group=0), 3)


@pytest.mark.parametrize("depth", [2, 4])
def test_tma_deep_ring_streams_exactly(depth):
    """Bulk-copy rings: the shared per-slot barrier must pair each wait with
    exactly its slot's arrivals across a stream longer than the ring."""
    run_pipeline(PipelineSpec(strategy=Strategy.TMA, depth=depth,
                              out_depth=3), 8)


@pytest.mark.parametrize("strategy", [Strategy.OVERLAP, Strategy.DROP_OFF,
                                      Strategy.TMA])
@pytest.mark.parametrize("n_tiles", [2, 5])
def test_traced_n_tiles(strategy, n_tiles):
    """A runtime tile count (flash attention's causal hi-lo) with a ring
    deeper than the stream: the warm-up guards must become pl.when and the
    clamped warm-up indices must keep the trace in bounds."""
    run_pipeline(PipelineSpec(strategy=strategy, depth=4), n_tiles,
                 traced=True)


def test_bare_strategy_coerces_via_as_spec():
    run_pipeline(Strategy.OVERLAP, 4)
    assert as_spec(Strategy.DROP_OFF, depth=3).ring_depth == 3
    assert as_spec(PipelineSpec(depth=5)) == PipelineSpec(depth=5)
