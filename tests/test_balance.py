"""Machine-balance tests promised by core/balance.py: the paper's §6
expectation model and Fig. 1 balance derivations over the Table 1 lineage."""
import math

import pytest

from repro.core import balance, hardware

DATACENTER_LINEAGE = ["K80", "P100", "V100", "A100"]


def test_v100_to_a100_expected_speedup_is_bw_bound():
    """Paper §6: V100→A100 = min(FLOP ratio 1.38, BW ratio 1.73) = 1.38x."""
    v100 = hardware.get_chip("V100")
    a100 = hardware.get_chip("A100")
    flop_ratio = a100.tflops_f32 / v100.tflops_f32
    bw_ratio = a100.mem_bw_gbs / v100.mem_bw_gbs
    assert flop_ratio == pytest.approx(1.38, abs=0.01)
    assert bw_ratio == pytest.approx(1.73, abs=0.01)
    t = balance.expected_speedup(v100, a100)
    assert t == pytest.approx(1.38, abs=0.01)
    assert t == min(flop_ratio, bw_ratio)       # the FLOP term binds
    # f64 behaves the same way on this pair
    assert balance.expected_speedup(v100, a100, "f64") == pytest.approx(
        a100.tflops_f64 / v100.tflops_f64, abs=0.01)


def test_datacenter_lineage_capability_monotone():
    """Across Table 1's datacenter lineage both roofline ceilings only go
    up, so every generational expected speedup is >= 1 (B/F may wobble —
    the paper's Fig. 1 point — but neither ceiling ever regresses)."""
    chips = [hardware.get_chip(n) for n in DATACENTER_LINEAGE]
    for old, new in zip(chips, chips[1:]):
        assert new.mem_bw_gbs > old.mem_bw_gbs, (old.name, new.name)
        assert new.tflops_f32 > old.tflops_f32, (old.name, new.name)
        assert balance.expected_speedup(old, new) >= 1.0
        assert balance.expected_speedup(new, old) <= 1.0  # and reverses


def test_machine_balance_bytes_per_flop_range():
    """B/F across the full Table 1 lineage: every GPU sits well below
    1 byte/flop (fp32) and the A100 has the highest datacenter fp32 B/F —
    the 'bandwidth kept pace' claim behind its async-copy features."""
    table = balance.lineage_table()
    for name in DATACENTER_LINEAGE:
        bf = table[name].bf_f32
        assert 0.0 < bf < 1.0
    dc = {n: table[n].bf_f32 for n in DATACENTER_LINEAGE}
    assert max(dc, key=dc.get) == "A100"
    # consumer parts are starved relative to their datacenter contemporaries
    assert table["GTX1050Ti"].bf_f32 < table["P100"].bf_f32
    assert table["RTX2060S"].bf_f64 > 1.0        # crippled f64: B/F explodes


def test_ridge_point_consistent_with_balance():
    for name in DATACENTER_LINEAGE:
        chip = hardware.get_chip(name)
        ridge = balance.ridge_point(chip)
        bf = balance.machine_balance(chip).bf_f32
        # ridge (flops/byte) is the reciprocal of balance (bytes/flop)
        assert ridge * bf == pytest.approx(1.0, rel=1e-9)


def test_roofline_time_and_attainable_flops():
    a100 = hardware.get_chip("A100")
    peak = a100.tflops_f32 * 1e12
    bw = a100.mem_bw_gbs * 1e9
    # compute-bound: high intensity pins the compute term
    t = balance.roofline_time(flops=peak, bytes_moved=1.0, chip=a100)
    assert t == pytest.approx(1.0)
    # memory-bound: low intensity pins the bandwidth term
    t = balance.roofline_time(flops=1.0, bytes_moved=bw, chip=a100)
    assert t == pytest.approx(1.0)
    # attainable flops bends at the ridge
    ridge = balance.ridge_point(a100)
    assert balance.attainable_flops(ridge / 10, a100) == pytest.approx(
        peak / 10)
    assert balance.attainable_flops(ridge * 10, a100) == pytest.approx(peak)


def test_density_increases_kepler_to_ampere():
    """Fig. 1's other axis: compute density (GFLOPS/mm^2) grows K80→A100."""
    k80 = balance.machine_balance(hardware.get_chip("K80"))
    a100 = balance.machine_balance(hardware.get_chip("A100"))
    assert a100.density_f32 > 3 * k80.density_f32
    assert not math.isnan(k80.density_f64)
