"""Machine-balance tests promised by core/balance.py: the paper's §6
expectation model and Fig. 1 balance derivations over the Table 1 lineage —
now extended past Ampere into Hopper — plus the chip-catalog invariants the
lineage validation (repro.bench.lineage) relies on."""
import inspect
import math

import pytest

from repro.core import balance, hardware
from repro.core.async_pipeline import Strategy, parse_strategy

#: the full datacenter arc, Hopper included (hardware.DATACENTER_LINEAGE);
#: a module alias so each assertion below reads at paper granularity
DATACENTER_LINEAGE = list(hardware.DATACENTER_LINEAGE)


def test_v100_to_a100_expected_speedup_is_bw_bound():
    """Paper §6: V100→A100 = min(FLOP ratio 1.38, BW ratio 1.73) = 1.38x."""
    v100 = hardware.get_chip("V100")
    a100 = hardware.get_chip("A100")
    flop_ratio = a100.tflops_f32 / v100.tflops_f32
    bw_ratio = a100.mem_bw_gbs / v100.mem_bw_gbs
    assert flop_ratio == pytest.approx(1.38, abs=0.01)
    assert bw_ratio == pytest.approx(1.73, abs=0.01)
    t = balance.expected_speedup(v100, a100)
    assert t == pytest.approx(1.38, abs=0.01)
    assert t == min(flop_ratio, bw_ratio)       # the FLOP term binds
    # f64 behaves the same way on this pair
    assert balance.expected_speedup(v100, a100, "f64") == pytest.approx(
        a100.tflops_f64 / v100.tflops_f64, abs=0.01)


def test_datacenter_lineage_capability_monotone():
    """Across Table 1's datacenter lineage both roofline ceilings only go
    up, so every generational expected speedup is >= 1 (B/F may wobble —
    the paper's Fig. 1 point — but neither ceiling ever regresses)."""
    chips = [hardware.get_chip(n) for n in DATACENTER_LINEAGE]
    for old, new in zip(chips, chips[1:]):
        assert new.mem_bw_gbs > old.mem_bw_gbs, (old.name, new.name)
        assert new.tflops_f32 > old.tflops_f32, (old.name, new.name)
        assert balance.expected_speedup(old, new) >= 1.0
        assert balance.expected_speedup(new, old) <= 1.0  # and reverses


def test_machine_balance_bytes_per_flop_range():
    """B/F across the full Table 1 lineage: every GPU sits well below
    1 byte/flop (fp32) and the A100 has the highest datacenter fp32 B/F —
    the 'bandwidth kept pace' claim behind its async-copy features."""
    table = balance.lineage_table()
    for name in DATACENTER_LINEAGE:
        bf = table[name].bf_f32
        assert 0.0 < bf < 1.0
    dc = {n: table[n].bf_f32 for n in DATACENTER_LINEAGE}
    assert max(dc, key=dc.get) == "A100"
    # consumer parts are starved relative to their datacenter contemporaries
    assert table["GTX1050Ti"].bf_f32 < table["P100"].bf_f32
    assert table["RTX2060S"].bf_f64 > 1.0        # crippled f64: B/F explodes


def test_ridge_point_consistent_with_balance():
    for name in DATACENTER_LINEAGE:
        chip = hardware.get_chip(name)
        ridge = balance.ridge_point(chip)
        bf = balance.machine_balance(chip).bf_f32
        # ridge (flops/byte) is the reciprocal of balance (bytes/flop)
        assert ridge * bf == pytest.approx(1.0, rel=1e-9)


def test_roofline_time_and_attainable_flops():
    a100 = hardware.get_chip("A100")
    peak = a100.tflops_f32 * 1e12
    bw = a100.mem_bw_gbs * 1e9
    # compute-bound: high intensity pins the compute term
    t = balance.roofline_time(flops=peak, bytes_moved=1.0, chip=a100)
    assert t == pytest.approx(1.0)
    # memory-bound: low intensity pins the bandwidth term
    t = balance.roofline_time(flops=1.0, bytes_moved=bw, chip=a100)
    assert t == pytest.approx(1.0)
    # attainable flops bends at the ridge
    ridge = balance.ridge_point(a100)
    assert balance.attainable_flops(ridge / 10, a100) == pytest.approx(
        peak / 10)
    assert balance.attainable_flops(ridge * 10, a100) == pytest.approx(peak)


def test_density_increases_kepler_to_ampere():
    """Fig. 1's other axis: compute density (GFLOPS/mm^2) grows K80→A100."""
    k80 = balance.machine_balance(hardware.get_chip("K80"))
    a100 = balance.machine_balance(hardware.get_chip("A100"))
    assert a100.density_f32 > 3 * k80.density_f32
    assert not math.isnan(k80.density_f64)


# --- chip-catalog invariants (the lineage validation's substrate) -----------


def test_catalog_names_unique_and_rates_positive():
    """CATALOG is keyed by name, so a duplicated row would silently shadow;
    and every chip must carry positive bandwidth/f32 peaks (the two ratios
    every expectation is built from)."""
    rows = hardware.GPUS + hardware.HOPPER + hardware.TPUS
    assert len({c.name for c in rows}) == len(rows)
    assert set(hardware.CATALOG) == {c.name for c in rows}
    for chip in hardware.CATALOG.values():
        assert chip.mem_bw_gbs > 0, chip.name
        assert chip.tflops_f32 > 0, chip.name
        assert chip.tflops_f64 >= 0, chip.name


def test_expected_speedup_identity_for_every_chip():
    for chip in hardware.CATALOG.values():
        assert balance.expected_speedup(chip, chip) == 1.0


def test_datacenter_lineage_extends_through_hopper():
    """The committed arc is K80→P100→V100→A100→H100-SXM: every name resolves,
    every generation strictly raises both roofline ceilings (which is why
    H200 — equal peak FLOPs to H100-SXM — is a pair, not a lineage step)."""
    assert DATACENTER_LINEAGE == ["K80", "P100", "V100", "A100", "H100-SXM"]
    chips = [hardware.get_chip(n) for n in DATACENTER_LINEAGE]
    for old, new in zip(chips, chips[1:]):
        assert new.mem_bw_gbs > old.mem_bw_gbs, (old.name, new.name)
        assert new.tflops_f32 > old.tflops_f32, (old.name, new.name)
        assert balance.expected_speedup(old, new) > 1.0
    for chip in chips:
        assert chip.grade == "datacenter"


def test_a100_to_h100_expectation_matches_published():
    """The tentpole's predictive claim: A100→H100-SXM is bandwidth-bound at
    ~2.16x (HBM3/HBM2e), not the 3.43x FLOP ratio."""
    exp = balance.expect_speedup(hardware.get_chip("A100"),
                                 hardware.get_chip("H100-SXM"))
    assert exp.binds == "bandwidth"
    assert exp.expected == pytest.approx(2.156, abs=0.01)
    assert exp.flop_ratio == pytest.approx(3.43, abs=0.01)


def test_expected_speedup_f64_raises_for_chips_without_f64():
    """The old silent inf/nan: TPUs carry tflops_f64=0.0 sentinels, so an
    f64 ratio against them is undefined and must raise, not propagate."""
    k80 = hardware.get_chip("K80")
    v5e = hardware.get_chip("TPUv5e")
    v5p = hardware.get_chip("TPUv5p")
    with pytest.raises(ValueError, match="no f64 units"):
        balance.expected_speedup(k80, v5e, precision="f64")   # old: inf
    with pytest.raises(ValueError, match="no f64 units"):
        balance.expected_speedup(v5e, k80, precision="f64")
    with pytest.raises(ValueError, match="no f64 units"):
        balance.expected_speedup(v5e, v5p, precision="f64")   # old: nan
    with pytest.raises(ValueError, match="unknown precision"):
        balance.expected_speedup(k80, v5e, precision="f16")
    with pytest.raises(ValueError, match="no f64 units"):
        balance.roofline_time(1.0, 1.0, v5e, precision="f64")


def test_machine_balance_f64_and_density_nan_for_sentinels():
    """machine_balance's contract matches: NaN (rendered "n/a"), never a
    number derived from a 0.0 sentinel."""
    v5e = balance.machine_balance(hardware.get_chip("TPUv5e"))
    assert math.isnan(v5e.bf_f64)           # no f64 units
    assert math.isnan(v5e.density_f32)      # die area unpublished
    assert math.isnan(v5e.density_f64)
    h100 = balance.machine_balance(hardware.get_chip("H100-SXM"))
    assert not math.isnan(h100.bf_f64)
    assert not math.isnan(h100.density_f32)


def test_lineage_table_signature_takes_no_precision():
    """Regression pin for the satellite fix: lineage_table() once accepted
    (and silently ignored) a precision parameter."""
    assert list(inspect.signature(balance.lineage_table).parameters) == []
    table = balance.lineage_table()
    assert set(table) == set(hardware.CATALOG)


def test_parse_strategy_round_trips_every_strategy_incl_tma():
    assert parse_strategy("tma") is Strategy.TMA
    for s in Strategy:
        assert parse_strategy(s.value) is s
        assert parse_strategy(s.value.upper()) is s
