import os
import sys

# tests must see the single real CPU device (the dry-run, and only the
# dry-run, forces 512 placeholder devices via its own XLA_FLAGS)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
