"""repro.bench subsystem tests: canonical timing (regression-locked to the
seed autotuner's statistics), scenario registry + CLI list, schema-v2
result round-trip with v1 upgrade, and runner provenance."""
import json
import os
import statistics
import sys
import time

import jax.numpy as jnp
import pytest

from repro.bench import (BenchReport, BenchResult, ResultSchemaMismatch,
                         SCHEMA_VERSION, Scenario, TimingStats, register,
                         scenarios, time_callable)
from repro.bench import runner, scenario as scenario_mod
from repro.bench.cli import main as bench_cli_main
from repro.bench.results import upgrade_v1_row
from repro.bench.timing import reject_outliers
from repro.core import hardware
from repro.core.async_pipeline import Strategy
from repro.tuning import Measurement, Registry, TuningRecord, make_key


# --- timing: identical statistics to the seed autotuner's implementation ---

def _seed_reject_outliers(times, k):
    """The deleted tuning/autotuner.py:_reject_outliers, verbatim — the
    regression oracle for the shared implementation."""
    if len(times) < 4 or k <= 0:
        return list(times)
    s = sorted(times)
    q1 = s[len(s) // 4]
    q3 = s[(3 * len(s)) // 4]
    cut = statistics.median(s) + k * max(q3 - q1, 1e-9)
    kept = [t for t in times if t <= cut]
    return kept or list(times)


@pytest.mark.parametrize("times", [
    [],
    [5.0],
    [1.0, 2.0, 3.0],                       # < 4 samples: untouched
    [10.0, 11.0, 12.0, 13.0, 14.0],        # tight: nothing rejected
    [10.0, 11.0, 12.0, 13.0, 500.0],       # one slow outlier
    [1.0, 1.0, 1.0, 1.0, 1.0],             # zero IQR: epsilon path
    [100.0, 3.0, 2.0, 1.0, 2.5, 2.0],      # outlier first, order kept
    [9e9, 9e9, 9e9, 9e9],                  # all identical huge
])
def test_reject_outliers_matches_seed_autotuner(times):
    for k in (0.0, 1.5, 3.0):
        assert reject_outliers(times, k) == _seed_reject_outliers(times, k)


def test_timing_stats_match_statistics_module():
    s = TimingStats(times_us=[4.0, 1.0, 3.0, 2.0], n_outliers=1)
    assert s.median == statistics.median([4.0, 1.0, 3.0, 2.0])
    assert s.mean == statistics.fmean([4.0, 1.0, 3.0, 2.0])
    assert s.best == 1.0
    assert s.std == statistics.pstdev([4.0, 1.0, 3.0, 2.0])
    m = s.to_metrics()
    assert m["n_trials"] == 4 and m["n_outliers"] == 1
    assert m["us_median"] == s.median
    empty = TimingStats(times_us=[])
    assert (empty.median, empty.mean, empty.best, empty.std) == (0, 0, 0, 0)


def test_time_callable_counts_warmup_and_repeats():
    calls = []
    fn = lambda: (calls.append(1), jnp.zeros(()))[1]
    stats = time_callable(fn, warmup=2, repeats=3, outlier_iqr=0)
    assert len(calls) == 5
    assert len(stats.times_us) == 3
    calls.clear()
    time_callable(fn, warmup=0, repeats=1)      # warmup=0 honored
    assert len(calls) == 1


def test_autotuner_owns_no_timing_loop():
    """The tuner must import the canonical timer, not hand-roll one."""
    from repro.tuning import autotuner
    from repro.bench import timing
    assert autotuner.time_callable is timing.time_callable
    assert autotuner.TimingStats is timing.TimingStats
    src = open(autotuner.__file__).read()
    assert "perf_counter" not in src


def test_warmup_zero_compile_cost_is_outlier_rejected():
    """warmup=0 lands the expensive first call in the timings — the IQR
    rejection must flag it instead of silently poisoning the median."""
    state = {"first": True}

    def fn():
        if state["first"]:
            state["first"] = False
            time.sleep(0.05)                # "compile" on first call
        return jnp.zeros(())

    stats = time_callable(fn, warmup=0, repeats=5)
    assert stats.n_outliers >= 1
    assert stats.median < 50_000            # the 50ms call didn't win


def test_repeats_one_yields_single_trial():
    stats = time_callable(lambda: jnp.zeros(()), warmup=0, repeats=1)
    assert len(stats.times_us) == 1 and stats.n_outliers == 0
    assert stats.median == stats.mean == stats.best == stats.times_us[0]
    assert stats.std == 0.0


def test_outlier_flags_edges():
    from repro.bench.timing import outlier_flags
    assert outlier_flags([], 3.0) == []
    assert outlier_flags([1.0, 2.0, 3.0], 3.0) == [False] * 3   # < 4 kept
    assert outlier_flags([1.0, 2.0, 3.0, 500.0], 0.0) == [False] * 4
    flags = outlier_flags([10.0, 11.0, 12.0, 13.0, 500.0], 3.0)
    assert flags == [False, False, False, False, True]
    # order preserved: the outlier keeps its position
    flags = outlier_flags([500.0, 10.0, 11.0, 12.0, 13.0], 3.0)
    assert flags == [True, False, False, False, False]
    # degenerate all-flagged case degrades to keep-all, never to empty
    assert reject_outliers([9e9, 9e9, 9e9, 9e9], 3.0) == [9e9] * 4


def test_time_callable_emits_trial_spans_under_open_span():
    """Traced timing: one warmup + one timed span per trial, all nested
    under whatever span the caller holds open, outlier-flagged."""
    from repro.obs.trace import tracer
    t = tracer()
    t.clear()
    t.enable()
    try:
        state = {"n": 0}

        def fn():
            state["n"] += 1
            if state["n"] == 2:             # call 2 = timed trial 0 (call
                #                             1 was the warmup): the outlier
                time.sleep(0.05)
            return jnp.zeros(())

        with t.span("scenario:test") as outer:
            stats = time_callable(fn, warmup=1, repeats=5)
    finally:
        t.disable()
    spans = t.spans()
    warm = [s for s in spans if s.name == "warmup"]
    timed = [s for s in spans if s.name == "timed"]
    assert len(warm) == 1 and len(timed) == 5
    assert all(s.parent_id == outer.span_id for s in warm + timed)
    assert [s.attrs["trial"] for s in timed] == list(range(5))
    flagged = [s for s in timed if s.attrs["outlier"]]
    assert len(flagged) == stats.n_outliers >= 1
    assert flagged[0].attrs["trial"] == 0
    # span durations are the real perf_counter readings, not re-measured
    assert flagged[0].dur_us == pytest.approx(50_000, rel=0.5)
    t.clear()


def test_time_callable_disabled_tracing_adds_no_spans():
    from repro.obs.trace import tracer
    t = tracer()
    t.clear()
    assert not t.enabled
    time_callable(lambda: jnp.zeros(()), warmup=1, repeats=2)
    assert t.spans() == []


def test_run_scenario_stamps_trace_id_when_traced(tmp_path):
    from repro.obs.trace import tracer
    sc = scenario_mod.get_scenario("smoke/stream")
    opts = runner.RunOptions(warmup=0, repeats=1, check=False,
                             registry=Registry(str(tmp_path / "reg.json")))
    r = runner.run_scenario(sc, opts)
    assert r.trace_id is None               # untraced rows carry no id
    t = tracer()
    t.clear()
    t.enable()
    try:
        r = runner.run_scenario(sc, opts)
    finally:
        t.disable()
    spans = {s.span_id: s for s in t.spans()}
    assert r.trace_id in spans
    scen = spans[r.trace_id]
    assert scen.name == f"scenario:{sc.name}"
    assert scen.attrs["config_source"] == "default"
    assert scen.attrs["us_median"] == r.metrics["us_median"]
    # the trial spans hang off the row's scenario span
    timed = [s for s in t.spans() if s.name == "timed"]
    assert timed and all(s.parent_id == r.trace_id for s in timed)
    t.clear()


# --- scenario registry ------------------------------------------------------

def test_default_scenarios_cover_every_kernel():
    smoke = scenarios(smoke=True)
    assert {s.kernel for s in smoke} == set(scenario_mod.KERNELS)


def test_scenario_filters():
    assert all(s.kernel == "stream" for s in scenarios(kernel="stream"))
    fig4 = scenarios(tag="fig4")
    assert {s.kernel for s in fig4} == {"hotspot", "pathfinder", "nw", "lud"}
    overlap = scenarios(tag="fig4", strategy=Strategy.OVERLAP)
    assert all(s.strategy in (None, Strategy.OVERLAP) for s in overlap)
    assert scenarios(only="no-such-scenario") == []


def test_scenario_register_rejects_redefinition_and_unknown_kernel():
    sc = Scenario(name="test/tmp-cell", kernel="stream", shape=(64, 128))
    assert register(sc) is sc
    register(sc)                                 # idempotent re-register
    with pytest.raises(ValueError):
        register(Scenario(name="test/tmp-cell", kernel="stream",
                          shape=(128, 128)))
    with pytest.raises(KeyError):
        Scenario(name="test/bad", kernel="not-a-kernel", shape=(1,))


def test_cli_list_runs_nothing(capsys, monkeypatch):
    """`cli list` must enumerate without measuring a single kernel."""
    def boom(*a, **k):
        raise AssertionError("list must not time anything")
    monkeypatch.setattr(runner, "run_scenario", boom)
    monkeypatch.setattr(scenario_mod, "call_kernel", boom)
    assert bench_cli_main(["list"]) == 0
    out = capsys.readouterr().out
    for kernel in scenario_mod.KERNELS:
        assert f"smoke/{kernel}" in out
    assert bench_cli_main(["list", "--tag", "fig3"]) == 0
    out = capsys.readouterr().out
    assert "fig3/stream/overlap/iters=1" in out and "fig4" not in out


# --- results schema ---------------------------------------------------------

def _result(**kw):
    base = dict(
        scenario="smoke/stream", kernel="stream", shape=[256, 256],
        dtype="float32", strategy="overlap", chip="TPUv5e",
        metrics={"us_median": 12.5, "check_ok": True},
        config={"strategy": "overlap", "tile_rows": 8, "n_tiles": 4,
                "depth": 2},
        config_source="tuned", tuned_key="stream|256x256|float32|TPUv5e|interpret",
        kind="measured", section="smoke", interpret=True, backend="cpu",
        jax_version="0.4.37", created_at="2026-08-02T00:00:00+00:00")
    base.update(kw)
    return BenchResult(**base)


def test_report_round_trip_preserves_provenance(tmp_path):
    path = str(tmp_path / "BENCH_test.json")
    report = BenchReport(jax_version="0.4.37", backend="cpu")
    report.add(_result())
    report.save(path)
    raw = json.load(open(path))
    assert raw["schema_version"] == SCHEMA_VERSION == 2
    got = BenchReport.load(path)
    assert len(got) == 1
    r = got.results[0]
    assert r == _result()               # every field, incl. provenance
    assert r.chip == "TPUv5e" and r.strategy == "overlap"
    assert r.config_source == "tuned"
    assert r.tuned_key == "stream|256x256|float32|TPUv5e|interpret"


def test_v1_payload_upgraded_on_load(tmp_path):
    """The schema 1 -> 2 bump: old benchmarks/run.py payloads load as v2
    rows instead of being misread or rejected."""
    path = str(tmp_path / "BENCH_old.json")
    v1 = {"schema_version": 1,
          "rows": [{"table": "fig3a", "name": "iters=4",
                    "section": "Fig3a: model",
                    "metrics": {"intensity": 1.0, "overlap": 1.4}}]}
    json.dump(v1, open(path, "w"))
    got = BenchReport.load(path)
    r = got.results[0]
    assert r.scenario == "fig3a/iters=4"
    assert r.section == "Fig3a: model"
    assert r.metrics == {"intensity": 1.0, "overlap": 1.4}
    assert r.config_source == "legacy-v1"
    # and a re-save emits current-schema v2
    got.save(path)
    assert json.load(open(path))["schema_version"] == 2


def test_unknown_schema_version_raises():
    with pytest.raises(ResultSchemaMismatch):
        BenchReport.from_dict({"schema_version": 99, "rows": []})
    assert upgrade_v1_row({}).config_source == "legacy-v1"


# --- runner -----------------------------------------------------------------

def test_run_scenario_records_full_provenance(tmp_path):
    sc = scenario_mod.get_scenario("smoke/stream")
    reg = Registry(str(tmp_path / "reg.json"))
    opts = runner.RunOptions(warmup=1, repeats=2, registry=reg)
    r = runner.run_scenario(sc, opts)
    assert r.kernel == "stream" and r.shape == [256, 256]
    assert r.chip == hardware.TARGET.name
    assert r.strategy == "overlap"              # seed default strategy
    assert r.config_source == "default" and r.tuned_key is None
    assert r.kind == "measured" and r.interpret
    assert r.jax_version and r.backend and r.created_at
    m = r.metrics
    assert m["n_trials"] == 2 and m["us_median"] > 0
    assert m["check_ok"] and m["max_err"] <= scenario_mod.CHECK_TOL["stream"]
    assert m["predicted_us"] > 0


def test_run_scenario_resolves_tuned_config(tmp_path):
    """A tuning-registry winner for the exact cell must win over the seed
    default, and the row must say so."""
    sc = scenario_mod.get_scenario("smoke/stream")
    reg = Registry(str(tmp_path / "reg.json"))
    best = {"strategy": "register_bypass", "tile_rows": 16, "n_tiles": 4,
            "depth": 2}
    reg.put(TuningRecord(
        kernel="stream", shape=list(sc.shape), dtype="float32",
        chip=hardware.TARGET.name, best=best, best_us=10.0,
        measurements=[Measurement(config=best, us_median=10.0)],
        interpret=True))
    r = runner.run_scenario(sc, runner.RunOptions(repeats=1, registry=reg))
    assert r.config_source == "tuned"
    assert r.tuned_key == make_key("stream", sc.shape, "float32",
                                   hardware.TARGET.name, True)
    assert r.strategy == "register_bypass"
    assert r.config["tile_rows"] == 16


def test_project_scenario_covers_the_lineage():
    sc = scenario_mod.get_scenario("smoke/stream")
    rows = [runner.project_scenario(sc, chip) for chip in ("K80", "A100")]
    assert [r.chip for r in rows] == ["K80", "A100"]
    for r in rows:
        assert r.kind == "model"
        assert r.metrics["predicted_us"] > 0
        assert r.metrics["bound"] in ("compute", "memory")
    # newer silicon must never be predicted slower on the same workload
    assert rows[1].metrics["predicted_us"] <= rows[0].metrics["predicted_us"]


def test_cli_run_writes_machine_parseable_json(tmp_path, capsys):
    out = str(tmp_path / "row.json")
    rc = bench_cli_main(["run", "--only", "smoke/stream", "--repeats", "1",
                         "--registry", str(tmp_path / "reg.json"),
                         "--json", out])
    assert rc == 0
    d = json.load(open(out))
    assert d["schema_version"] == 2 and len(d["rows"]) == 1
    capsys.readouterr()


# --- regime map -------------------------------------------------------------

def _regime_row(strategy, us, depth=2, kernel="stream", **kw):
    base = dict(
        scenario=f"regime/{kernel}/{strategy}", kernel=kernel,
        shape=[256, 256], dtype="float32", strategy=strategy, chip="TPUv5e",
        metrics={"us_median": us},
        config={"strategy": strategy, "depth": depth},
        kind="measured", section="regime", interpret=True, backend="cpu")
    base.update(kw)
    return BenchResult(**base)


def test_regime_scenarios_registered_for_every_kernel():
    """The depth-sweep family: one sync baseline + the kernel's best async
    strategy AND the TMA bulk-copy strategy at each ring depth, per
    kernel."""
    regime = scenarios(tag="regime")
    assert {s.kernel for s in regime} == set(scenario_mod.KERNELS)
    for kernel in scenario_mod.KERNELS:
        cells = [s for s in regime if s.kernel == kernel]
        assert len(cells) == 7              # sync + 2 strategies x d2/d3/d4
        syncs = [s for s in cells if s.strategy is Strategy.SYNC]
        assert len(syncs) == 1 and not syncs[0].config.get("depth")
        by_strategy = {}
        for s in cells:
            if s.strategy is not Strategy.SYNC:
                by_strategy.setdefault(s.strategy, []).append(
                    s.config["depth"])
        assert Strategy.TMA in by_strategy
        assert len(by_strategy) == 2        # best-async + tma
        for depths in by_strategy.values():
            assert sorted(depths) == [2, 3, 4]
        assert all(s.section == "regime" for s in cells)


def test_regime_rows_verdicts_and_break_even():
    from repro.bench import regime_rows

    # async pays from depth 3 on: d2 regresses, d3/d4 beat the baseline
    rows = [_regime_row("sync", 100.0),
            _regime_row("overlap", 120.0, depth=2),
            _regime_row("overlap", 80.0, depth=3),
            _regime_row("overlap", 90.0, depth=4)]
    (r,) = regime_rows(rows)
    assert r.kind == "regime" and r.section == "regime"
    m = r.metrics
    assert m["verdict"] == "pays"
    assert m["break_even_depth"] == 3 and m["best_depth"] == 3
    assert m["baseline_us"] == 100.0 and m["best_us"] == 80.0
    assert m["speedup"] == pytest.approx(1.25)
    assert (m["us_d2"], m["us_d3"], m["us_d4"]) == (120.0, 80.0, 90.0)

    # async never reaches the baseline: hurts, no break-even depth
    rows = [_regime_row("sync", 100.0),
            _regime_row("overlap", 150.0, depth=2),
            _regime_row("overlap", 140.0, depth=3)]
    (r,) = regime_rows(rows)
    assert r.metrics["verdict"] == "hurts"
    assert r.metrics["break_even_depth"] is None

    # within the +/-5% margin: neutral (still has a break-even depth)
    rows = [_regime_row("sync", 100.0),
            _regime_row("overlap", 98.0, depth=2)]
    (r,) = regime_rows(rows)
    assert r.metrics["verdict"] == "neutral"
    assert r.metrics["break_even_depth"] == 2

    # partial sweeps never fabricate a verdict
    assert regime_rows([_regime_row("sync", 100.0)]) == []
    assert regime_rows([_regime_row("overlap", 80.0)]) == []
    assert regime_rows([_regime_row("sync", 100.0, section="fig3"),
                        _regime_row("overlap", 80.0, section="fig3")]) == []


def test_sweep_appends_regime_verdicts(tmp_path):
    """An end-to-end depth sweep over one kernel's regime cells must yield
    the measured rows (sync + overlap/tma x 3 depths), the projections,
    and exactly one verdict row (min across async strategies per depth)."""
    scs = scenarios(tag="regime", kernel="stream")
    assert len(scs) == 7
    opts = runner.RunOptions(warmup=0, repeats=1,
                             registry=Registry(str(tmp_path / "reg.json")))
    report = runner.sweep(scs, chips=["TPUv5e"], opts=opts)
    regime = [r for r in report.results if r.kind == "regime"]
    assert len(regime) == 1
    m = regime[0].metrics
    assert m["verdict"] in ("pays", "neutral", "hurts")
    assert {"us_d2", "us_d3", "us_d4"} <= set(m)
    assert m["baseline_us"] > 0
    # round-trips through the schema-v2 artifact
    path = str(tmp_path / "BENCH_regime.json")
    report.save(path)
    got = BenchReport.load(path)
    assert [r for r in got.results if r.kind == "regime"] == regime


# --- benchmarks/run.py shim -------------------------------------------------

def _import_benchmarks_run():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import run as bench_run
    return bench_run


def test_run_py_json_dash_keeps_stdout_pure(capsys):
    """--json - : the JSON payload owns stdout; progress goes to stderr."""
    bench_run = _import_benchmarks_run()
    bench_run.main(["--only", "bench_balance", "--json", "-"])
    captured = capsys.readouterr()
    payload = json.loads(captured.out)          # must parse as-is
    assert payload["schema_version"] == 2
    assert payload["rows"]
    assert "====" in captured.err               # progress went to stderr


def test_run_py_list_flag(capsys):
    bench_run = _import_benchmarks_run()
    bench_run.main(["--list"])
    out = capsys.readouterr().out
    assert "bench_balance(Fig1+S6)" in out
    assert "smoke/stream" in out                # scenario registry included
