"""Render the markdown tables for EXPERIMENTS.md: the §Dry-run / §Roofline
tables from the dry-run JSONs, and the benchmark tables from ``BENCH_*.json``
trajectory files (the ``repro.bench`` schema-v2 result format; legacy v1
payloads are upgraded on load).

    PYTHONPATH=src python experiments/make_report.py [--bench 'BENCH_*.json']
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DIR = os.path.join(os.path.dirname(__file__), "dryrun")

#: the lineage subset shown in the per-scenario projection table (the full
#: sweep covers every registered chip; the report keeps the paper's arc)
REPORT_CHIPS = ("K80", "P100", "V100", "A100", "TPUv5e")

def fmt_ms(s): return f"{s*1e3:,.1f}"


def dryrun_tables():
    recs = [json.load(open(f)) for f in sorted(glob.glob(f"{DIR}/*.json"))]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["mesh"], r["arch"], order.get(r["shape"], 9)))
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        print(f"\n### Mesh {mesh} ({'256 chips, single pod' if mesh=='16x16' else '512 chips, 2 pods'})\n")
        print("| arch | shape | HBM/chip (GB) | fits | t_compute (ms) | "
              "t_memory (ms) | t_collective (ms) | bottleneck | useful flops | roofline |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in sub:
            if r.get("status") == "skipped":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                      f"skipped (full attention @524k) | — | — |")
                continue
            if r.get("status") != "ok":
                print(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['hbm_per_chip_gb']:.2f} "
                  f"| {'Y' if r['fits_hbm'] else 'N'} "
                  f"| {fmt_ms(r['t_compute'])} | {fmt_ms(r['t_memory'])} "
                  f"| {fmt_ms(r['t_collective'])} | {r['bottleneck']} "
                  f"| {r['useful_flops_ratio']*100:.1f}% "
                  f"| {r['roofline_fraction']*100:.2f}% |")


def bench_tables(pattern):
    from repro.bench.results import BenchReport, ResultSchemaMismatch
    paths = sorted(glob.glob(pattern))
    if not paths:
        print(f"\n*(no benchmark trajectories match {pattern!r} — run "
              f"`python -m repro.bench.cli sweep --smoke --json "
              f"BENCH_sweep.json`)*")
        return
    for path in paths:
        try:
            report = BenchReport.load(path)
        except (ResultSchemaMismatch, json.JSONDecodeError, OSError) as e:
            print(f"\n*(skipping {path}: {e})*")
            continue
        print(f"\n### Benchmarks: {os.path.basename(path)} "
              f"(jax {report.jax_version or '?'}, "
              f"backend {report.backend or '?'}, {report.created_at})\n")
        measured = [r for r in report.results if r.kind == "measured"]
        serving = [r for r in measured if r.kernel == "serve"]
        measured = [r for r in measured if r.kernel != "serve"]
        if serving:
            print("| scenario | scheduler | batch | requests | tok/s "
                  "| ttft p50/p99 (ms) | decode p50/p99 (ms) | occupancy "
                  "| hit ratio | step us (median) |")
            print("|---|---|---|---|---|---|---|---|---|---|")
            for r in serving:
                m = r.metrics
                batch = r.shape[0] if r.shape else "—"
                # hit ratio exists only on chunked-prefill rows; '—' keeps
                # monolithic rows distinguishable from a measured 0.00
                hit = (f"{m['cache_hit_ratio']:.2f}"
                       if "cache_hit_ratio" in m else "—")
                print(f"| {r.scenario} | {r.strategy} | {batch} "
                      f"| {m.get('requests', 0):g} "
                      f"| {m.get('tokens_per_s', 0):,.0f} "
                      f"| {m.get('ttft_ms_p50', 0):,.0f} / "
                      f"{m.get('ttft_ms_p99', 0):,.0f} "
                      f"| {m.get('decode_ms_p50', 0):,.2f} / "
                      f"{m.get('decode_ms_p99', 0):,.2f} "
                      f"| {m.get('occupancy_mean', 0):.2f} "
                      f"| {hit} "
                      f"| {m.get('us_median', 0):,.1f} |")
            if measured:
                print()
        if measured:
            print("| scenario | chip | strategy | config | us (median) "
                  "| us (min) | max err | ok |")
            print("|---|---|---|---|---|---|---|---|")
            for r in measured:
                m = r.metrics
                ok = {True: "Y", False: "**N**"}.get(m.get("check_ok"), "—")
                err = (f"{m['max_err']:.1e}" if "max_err" in m else "—")
                print(f"| {r.scenario} | {r.chip} | {r.strategy} "
                      f"| {r.config_source} | {m.get('us_median', 0):,.1f} "
                      f"| {m.get('us_min', 0):,.1f} | {err} | {ok} |")
        regime = [r for r in report.results if r.kind == "regime"]
        if regime:
            print("\n**Async regime map** (measured; best async strategy at "
                  "each ring depth vs the sync baseline)\n")
            depths = sorted({int(k[4:]) for r in regime for k in r.metrics
                             if k.startswith("us_d")})
            head = " | ".join(f"us@d{d}" for d in depths)
            print(f"| kernel | shape | strategy | sync us | {head} "
                  "| break-even | speedup | verdict |")
            print("|---" * (7 + len(depths)) + "|")
            for r in regime:
                m = r.metrics
                cells = " | ".join(
                    f"{m[f'us_d{d}']:,.1f}" if f"us_d{d}" in m else "—"
                    for d in depths)
                be = m.get("break_even_depth")
                verdict = m["verdict"]
                if verdict != "neutral":
                    verdict = f"**{verdict}**"
                print(f"| {r.kernel} | {'x'.join(map(str, r.shape))} "
                      f"| {r.strategy} | {m['baseline_us']:,.1f} | {cells} "
                      f"| {f'd{be}' if be is not None else '—'} "
                      f"| {m['speedup']:.2f}x | {verdict} |")
        model = [r for r in report.results
                 if r.kind == "model" and r.chip in REPORT_CHIPS]
        if model:
            print("\n**Roofline projection across the lineage** "
                  "(predicted us; full chip set in the JSON)\n")
            chips = [c for c in REPORT_CHIPS
                     if any(r.chip == c for r in model)]
            print("| scenario | " + " | ".join(chips) + " |")
            print("|---" * (len(chips) + 1) + "|")
            by_cell = {(r.scenario, r.chip): r for r in model}
            for name in sorted({r.scenario for r in model}):
                cells = []
                for c in chips:
                    r = by_cell.get((name, c))
                    cells.append(f"{r.metrics['predicted_us']:,.2f}"
                                 if r else "—")
                print(f"| {name} | " + " | ".join(cells) + " |")
        legacy = [r for r in report.results if r.config_source == "legacy-v1"]
        if legacy:
            print(f"\n*({len(legacy)} legacy v1 rows upgraded; analytic "
                  f"figure rows keep their original table/name keys)*")


def compare_tables(pattern):
    """Render obs-compare verdict documents (the regression gate's output)."""
    from repro.obs.compare import CompareResult
    for path in sorted(glob.glob(pattern)):
        try:
            res = CompareResult.load(path)
        except (ValueError, json.JSONDecodeError, OSError) as e:
            print(f"\n*(skipping {path}: {e})*")
            continue
        c = res.counts()
        gate = "**REGRESSED**" if res.n_regressions else "ok"
        norm = (f", host scale {res.host_scale:.3f}" if res.normalized
                else "")
        print(f"\n### Regression gate: {os.path.basename(path)} "
              f"(k={res.k:g}, rel floor {res.rel_floor:.0%}{norm}) "
              f"— gate {gate}\n")
        print("| verdict | scenario | chip | base us | new us | band us "
              "| delta |")
        print("|---|---|---|---|---|---|---|")
        for v in res.verdicts:
            verdict = f"**{v.verdict}**" if v.verdict == "regress" \
                else v.verdict
            base = f"{v.base_us:,.1f}" if v.base_us is not None else "—"
            new = f"{v.adj_new_us:,.1f}" if v.adj_new_us is not None else \
                (f"{v.new_us:,.1f}" if v.new_us is not None else "—")
            delta = (f"{v.delta_pct:+.1f}%"
                     if v.verdict in ("pass", "regress", "improve") else "—")
            print(f"| {verdict} | {v.scenario} | {v.chip} | {base} | {new} "
                  f"| {v.band_us:,.2f} | {delta} |")
        print(f"\n*({c['pass']} pass, {c['regress']} regress, "
              f"{c['improve']} improve, {c['new']} new, "
              f"{c['missing']} missing)*")


def lineage_tables(pattern):
    """Render lineage-validation verdict documents (catalog expectations vs
    published chip-pair speedups, from `repro.bench.cli lineage --json`)."""
    for path in sorted(glob.glob(pattern)):
        try:
            doc = json.load(open(path))
        except (json.JSONDecodeError, OSError) as e:
            print(f"\n*(skipping {path}: {e})*")
            continue
        if doc.get("kind") != "lineage-validation":
            print(f"\n*(skipping {path}: not a lineage-validation doc)*")
            continue
        c = doc.get("counts", {})
        gate = "**DRIFTED**" if not doc.get("ok", True) else "ok"
        print(f"\n### Lineage validation: {os.path.basename(path)} "
              f"(reference {doc.get('reference', '?')}) — gate {gate}\n")
        chain = doc.get("chain", [])
        if chain:
            arc = chain[0]["old"] + " → " + " → ".join(
                r["new"] for r in chain)
            print(f"Catalog expectation arc ({chain[0]['precision']}): "
                  f"{arc}\n")
            print("| pair | expected | FLOP ratio | BW ratio | binds |")
            print("|---|---|---|---|---|")
            for r in chain:
                print(f"| {r['old']} → {r['new']} | {r['expected']:.2f}x "
                      f"| {r['flop_ratio']:.2f}x | {r['bw_ratio']:.2f}x "
                      f"| {r['binds']} |")
            print()
        print("| verdict | pair | prec | expected | published | dev "
              "| band | binds |")
        print("|---|---|---|---|---|---|---|---|")
        for r in doc.get("rows", []):
            verdict = r["verdict"]
            if verdict != "within-band":
                verdict = f"**{verdict}**"
            print(f"| {verdict} | {r['old']} → {r['new']} "
                  f"| {r['precision']} | {r['expected']:.2f}x "
                  f"| {r['published']:.2f}x | {r['rel_dev']:+.1%} "
                  f"| ±{r['band']:.0%} | {r['binds']} |")
        print(f"\n*({c.get('within-band', 0)} within-band, "
              f"{c.get('over', 0)} over, {c.get('under', 0)} under)*")


def metrics_tables(pattern):
    """Render obs-metrics snapshots (serving TTFT/latency/occupancy)."""
    for path in sorted(glob.glob(pattern)):
        try:
            doc = json.load(open(path))
        except (json.JSONDecodeError, OSError) as e:
            print(f"\n*(skipping {path}: {e})*")
            continue
        if doc.get("kind") != "obs-metrics":
            print(f"\n*(skipping {path}: not an obs-metrics snapshot)*")
            continue
        print(f"\n### Serving metrics: {os.path.basename(path)}\n")
        print("| metric | labels | kind | count | mean | p50 | p90 | p99 "
              "| value |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in doc.get("rows", []):
            labels = ",".join(f"{k}={v}" for k, v in
                              sorted(r.get("labels", {}).items())) or "—"
            if r["kind"] == "histogram":
                print(f"| {r['name']} | {labels} | histogram "
                      f"| {r['count']} | {r['mean']:,.2f} | {r['p50']:,.2f} "
                      f"| {r['p90']:,.2f} | {r['p99']:,.2f} | — |")
            else:
                print(f"| {r['name']} | {labels} | {r['kind']} | — | — | — "
                      f"| — | — | {r['value']:g} |")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="BENCH_*.json", metavar="GLOB",
                    help="benchmark trajectory files to render "
                         "(default: BENCH_*.json in the cwd)")
    ap.add_argument("--compare", default=None, metavar="GLOB",
                    help="obs-compare verdict JSONs (from "
                         "`python -m repro.obs.cli compare --json`)")
    ap.add_argument("--metrics", default=None, metavar="GLOB",
                    help="obs-metrics snapshots (from serve --metrics-json)")
    ap.add_argument("--lineage", default=None, metavar="GLOB",
                    help="lineage-validation verdict JSONs (from "
                         "`python -m repro.bench.cli lineage --json`)")
    ap.add_argument("--no-dryrun", action="store_true",
                    help="skip the dry-run roofline tables")
    args = ap.parse_args(argv)
    if not args.no_dryrun:
        dryrun_tables()
    bench_tables(args.bench)
    if args.compare:
        compare_tables(args.compare)
    if args.metrics:
        metrics_tables(args.metrics)
    if args.lineage:
        lineage_tables(args.lineage)


if __name__ == "__main__":
    main()
