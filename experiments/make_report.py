"""Render the §Dry-run / §Roofline markdown tables from the dry-run JSONs."""
import glob, json, os, sys

DIR = os.path.join(os.path.dirname(__file__), "dryrun")

def fmt_ms(s): return f"{s*1e3:,.1f}"

def main():
    recs = [json.load(open(f)) for f in sorted(glob.glob(f"{DIR}/*.json"))]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["mesh"], r["arch"], order.get(r["shape"], 9)))
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        print(f"\n### Mesh {mesh} ({'256 chips, single pod' if mesh=='16x16' else '512 chips, 2 pods'})\n")
        print("| arch | shape | HBM/chip (GB) | fits | t_compute (ms) | "
              "t_memory (ms) | t_collective (ms) | bottleneck | useful flops | roofline |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in sub:
            if r.get("status") == "skipped":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                      f"skipped (full attention @524k) | — | — |")
                continue
            if r.get("status") != "ok":
                print(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['hbm_per_chip_gb']:.2f} "
                  f"| {'Y' if r['fits_hbm'] else 'N'} "
                  f"| {fmt_ms(r['t_compute'])} | {fmt_ms(r['t_memory'])} "
                  f"| {fmt_ms(r['t_collective'])} | {r['bottleneck']} "
                  f"| {r['useful_flops_ratio']*100:.1f}% "
                  f"| {r['roofline_fraction']*100:.2f}% |")

if __name__ == "__main__":
    main()
