"""Profile one dry-run cell: top ops by weighted bytes / flops / wire.
    PYTHONPATH=src python experiments/profile_cell.py <arch> <shape>"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp
from repro.core.hlo_cost import top_costs
import repro.launch.dryrun as D
import repro.launch.train as T
from repro.configs import get_config
from repro.core.config import RunConfig, get_shape
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.optim import adamw_init, moment_shardings
from repro.launch.mesh import make_production_mesh


def compile_cell(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh()
    import numpy as np
    data = int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.shape]))
    micro = max(1, shape.global_batch // data) if shape.mode == "train" else 1
    from repro.core import hardware
    tp = mesh.shape.get("model", 1)
    state_gb = cfg.param_count() * 4 * 3.3 / tp / 2 ** 30
    fsdp = shape.mode == "train" and state_gb > 0.5 * (hardware.HBM_BYTES / 2 ** 30)
    run = RunConfig(microbatches=micro, fsdp=fsdp)
    model = build_model(cfg)
    with jax.set_mesh(mesh):
        rules = D.build_rules(mesh, cfg, shape, shape.mode, run)
        with shd.use_rules(rules):
            p_shapes, p_axes = D.abstract_params(model)
        if shape.mode in ("prefill", "decode"):
            p_shapes = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype), p_shapes)
        p_sh = shd.tree_shardings_safe(p_axes, p_shapes, rules)
        specs = D.input_specs(cfg, shape)
        b_sh = D.batch_shardings(specs, rules)
        if shape.mode == "train":
            T.set_param_axes(p_axes)
            opt_shapes = jax.eval_shape(adamw_init, p_shapes)
            msh = moment_shardings(p_axes, jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), p_shapes), rules)
            opt_sh = type(opt_shapes)(step=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), m=msh, v=msh)
            comp = jax.jit(T.build_train_step(model, run, rules),
                           in_shardings=(p_sh, opt_sh, b_sh,
                                         jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())),
                           donate_argnums=(0, 1)).lower(
                p_shapes, opt_shapes, specs,
                jax.ShapeDtypeStruct((), jnp.int32)).compile()
        elif shape.mode == "prefill":
            def prefill_fn(params, batch):
                with shd.use_rules(rules):
                    return model.prefill(params, batch)
            comp = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh)).lower(
                p_shapes, specs).compile()
        else:
            st_shapes, st_sh = D.state_specs(cfg, shape, rules)
            def decode_fn(params, state, tokens):
                with shd.use_rules(rules):
                    return model.decode_step(params, state, tokens)
            comp = jax.jit(decode_fn, in_shardings=(p_sh, st_sh, b_sh["tokens"]),
                           donate_argnums=(1,)).lower(
                p_shapes, st_shapes, specs["tokens"]).compile()
    return comp


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    comp = compile_cell(arch, shape)
    by_bytes, by_flops, by_wire = top_costs(comp.as_text(), k=10)
    print(f"=== {arch} {shape}: top weighted fused-bytes ops ===")
    for wb, w, line in by_bytes:
        print(f"{wb:.3e} (w={w:.0f}) {line[:120]}")
    print("=== top weighted flops ===")
    for wf, w, line in by_flops[:6]:
        print(f"{wf:.3e} (w={w:.0f}) {line[:120]}")
    print("=== top weighted wire ===")
    for ww, w, line in by_wire[:8]:
        print(f"{ww:.3e} (w={w:.0f}) {line[:120]}")


if __name__ == "__main__":
    main()
