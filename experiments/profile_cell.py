"""Profile one dry-run cell: top ops by weighted bytes / flops / wire.
    PYTHONPATH=src python experiments/profile_cell.py <arch> <shape>

Thin shim over ``repro.launch.profile`` (also reachable as
``python -m repro.obs.cli profile``).  The host-device-count flag is
APPENDED to any pre-set ``XLA_FLAGS`` — a bare overwrite here used to
silently drop flags the caller exported (e.g. dump_to/deterministic-ops).
The append happens inline, before any repro/jax import, so it is in place
no matter when the backend initializes.
"""
import os
import sys

_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}=512".strip()
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.profile import (compile_cell,  # noqa: F401,E402  (re-exported for callers of the old module)
                                  format_report, profile_report)


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    print(format_report(arch, shape, profile_report(arch, shape, k=10)))


if __name__ == "__main__":
    main()
