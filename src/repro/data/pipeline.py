"""Synthetic-but-deterministic data pipeline with host-side async prefetch.

The paper's Overlap pattern at the host level: a background thread produces
batch t+1 (and initiates its device transfer) while the training step
consumes batch t.  Batches are a pure function of (seed, step), which is what
makes checkpoint-restart and elastic re-sharding bitwise reproducible: after
a restore at step k, the pipeline replays batch k identically on any mesh.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig, ShapeConfig


def synth_batch(cfg: ArchConfig, *, batch: int, seq: int, seed: int,
                step: int) -> Dict[str, np.ndarray]:
    """Deterministic synthetic LM batch for (seed, step)."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(step))
    n_text = seq - (cfg.n_patches if cfg.n_patches else 0)
    if cfg.is_encdec:
        n_text = seq // 2
    # a learnable synthetic language: tokens follow a noisy affine recurrence
    # so the loss has signal to descend (pure-uniform tokens would not).
    t0 = rng.integers(0, cfg.vocab, (batch, 1))
    steps = rng.integers(0, 7, (batch, n_text - 1))
    toks = (np.cumsum(np.concatenate([t0, steps], axis=1), axis=1)
            % cfg.vocab).astype(np.int32)
    out: Dict[str, np.ndarray] = {
        "tokens": toks,
        "labels": np.concatenate(
            [toks[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1),
    }
    if cfg.n_patches:
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        out["frames"] = rng.standard_normal(
            (batch, seq - n_text, cfg.d_model)).astype(np.float32)
    return out


class Prefetcher:
    """Double-buffered host->device prefetch (the Overlap pattern)."""

    def __init__(self, cfg: ArchConfig, *, batch: int, seq: int, seed: int,
                 start_step: int = 0, shardings: Optional[dict] = None,
                 depth: int = 2):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.shardings = shardings
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _produce(self, step: int):
        host = synth_batch(self.cfg, batch=self.batch, seq=self.seq,
                           seed=self.seed, step=step)
        if self.shardings:
            return {k: jax.device_put(v, self.shardings.get(k))
                    for k, v in host.items()}
        return {k: jnp.asarray(v) for k, v in host.items()}

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self.q.put(self._produce(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
