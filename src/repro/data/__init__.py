from .pipeline import Prefetcher, synth_batch

__all__ = ["Prefetcher", "synth_batch"]
