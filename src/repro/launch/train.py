"""Training step construction + the fault-tolerant training driver.

``build_train_step`` returns a jit-able pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
with gradient-accumulation microbatching (activation memory ~ 1/A), optional
bf16 gradient-accumulator compression (the cross-replica reduce then moves
half the bytes), remat-inside-scan, and ZeRO-1 moment sharding constraints.

Run as a script it trains a reduced model end-to-end on the local device:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 50
"""
from __future__ import annotations

import argparse
import functools
import logging
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.config import ArchConfig, RunConfig
from ..distributed import sharding as shd
from ..distributed.fault_tolerance import (PreemptionGuard, StepStats,
                                           run_with_retries)
from ..models import build_model
from ..optim import adamw_init, adamw_update, lr_schedule, moment_shardings

log = logging.getLogger("repro.train")


def microbatch_split(batch: Dict[str, jax.Array], n: int):
    """(B, ...) -> (n, B/n, ...), keeping the batch dim data-sharded."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        x = x.reshape(n, b // n, *x.shape[1:])
        return shd.logical(x, None, "batch", *([None] * (x.ndim - 2)))
    return jax.tree.map(split, batch)


def build_train_step(model, run: RunConfig, rules=None):
    cfg: ArchConfig = model.cfg
    accum_dtype = jnp.bfloat16 if run.grad_compression == "bf16" \
        else jnp.float32

    def loss_fn(params, mb):
        return model.loss(params, mb, remat=run.remat)

    def train_step(params, opt_state, batch, step):
        with shd.use_rules(rules):
            a = run.microbatches
            if a > 1:
                mbs = microbatch_split(batch, a)

                def acc_body(carry, mb):
                    g_acc, metric_acc = carry
                    (_, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    g_acc = jax.tree.map(
                        lambda acc, g: acc + g.astype(accum_dtype),
                        g_acc, grads)
                    metric_acc = jax.tree.map(
                        lambda acc, m: acc + m.astype(jnp.float32),
                        metric_acc, metrics)
                    return (g_acc, metric_acc), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), params)
                m0 = {"ce": jnp.zeros(()), "aux": jnp.zeros(()),
                      "tokens": jnp.zeros(())}
                (grads, metrics), _ = jax.lax.scan(
                    acc_body, (g0, m0), mbs)
                grads = jax.tree.map(
                    lambda g: (g / a).astype(jnp.float32), grads)
                metrics = jax.tree.map(lambda m: m / a, metrics)
                metrics["tokens"] = metrics["tokens"] * a
            else:
                (_, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)

            lr = lr_schedule(step + 1, lr=run.lr, warmup=run.warmup_steps,
                             total=run.total_steps)
            params2, opt2, gnorm = adamw_update(
                grads, opt_state, params, lr=lr,
                weight_decay=run.weight_decay, clip_norm=run.clip_norm)
            if run.zero1 and rules is not None:
                mshard = _moment_shardings_for(params, rules)
                opt2 = opt2._replace(
                    m=jax.tree.map(jax.lax.with_sharding_constraint,
                                   opt2.m, mshard),
                    v=jax.tree.map(jax.lax.with_sharding_constraint,
                                   opt2.v, mshard))
            metrics = dict(metrics, grad_norm=gnorm, lr=lr)
            return params2, opt2, metrics

    return train_step


_AXES_CACHE: dict = {}


def set_param_axes(params_axes):
    """Register the logical axes tree (from split_tree) for ZeRO-1 specs."""
    _AXES_CACHE["axes"] = params_axes


def _moment_shardings_for(params, rules):
    axes = _AXES_CACHE.get("axes")
    if axes is None:
        raise RuntimeError("call set_param_axes(axes_tree) before building "
                           "a ZeRO-1 train step")
    shapes = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
                          params)
    return moment_shardings(axes, shapes, rules)


# ---------------------------------------------------------------------------
# End-to-end local training driver (examples + integration tests call this)
# ---------------------------------------------------------------------------

def train_loop(cfg: ArchConfig, run: RunConfig, *, steps: int,
               batch: int = 8, seq: int = 64,
               ckpt_dir: Optional[str] = None, resume: bool = False,
               log_every: int = 10, straggler_factor: float = 3.0):
    """Single-host training with checkpoint/restart + preemption handling."""
    from ..checkpoint import Checkpointer
    from ..data import synth_batch

    model = build_model(cfg)
    params_ann = model.init(jax.random.PRNGKey(run.seed))
    params, axes = shd.split_tree(params_ann)
    set_param_axes(axes)
    opt_state = adamw_init(params)
    start_step = 0

    ckpt = Checkpointer(ckpt_dir, async_save=True) if ckpt_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        restored = ckpt.restore({"params": params, "opt": opt_state})
        params = jax.tree.map(jnp.asarray, restored["params"])
        opt_state = jax.tree.map(jnp.asarray, restored["opt"])
        start_step = ckpt.latest_step()
        log.info("resumed from step %d", start_step)

    step_fn = jax.jit(build_train_step(model, run))
    stats = StepStats()
    history = []
    with PreemptionGuard() as guard:
        for step in range(start_step, steps):
            data = synth_batch(cfg, batch=batch, seq=seq, seed=run.seed,
                               step=step)
            data = {k: jnp.asarray(v) for k, v in data.items()}

            def do_step():
                return step_fn(params, opt_state, data,
                               jnp.asarray(step, jnp.int32))

            t0 = time.time()
            params, opt_state, metrics = run_with_retries(do_step)
            jax.block_until_ready(metrics["ce"])
            stats.record(step, time.time() - t0,
                         factor=straggler_factor)
            history.append(float(metrics["ce"]))
            if step % log_every == 0:
                log.info("step %d ce=%.4f gnorm=%.3f", step,
                         float(metrics["ce"]), float(metrics["grad_norm"]))
            if ckpt and (guard.requested or step == steps - 1):
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
                if guard.requested:
                    log.warning("preempted at step %d: state saved", step)
                    break
    if ckpt:
        ckpt.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tuning-registry", default=None,
                    help="autotuning registry JSON (default "
                         "./tuning_registry.json)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    from ..tuning import apply_tuned_kernel_defaults
    apply_tuned_kernel_defaults(args.tuning_registry)

    from ..configs import get_config, get_smoke_config
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))
    _, _, history = train_loop(cfg, run, steps=args.steps, batch=args.batch,
                               seq=args.seq, ckpt_dir=args.ckpt,
                               resume=args.resume)
    print(f"first-10 ce={sum(history[:10])/max(len(history[:10]),1):.4f} "
          f"last-10 ce={sum(history[-10:])/max(len(history[-10:]),1):.4f}")


if __name__ == "__main__":
    main()
