"""Serving driver: continuous batching over a paged KV cache.

The scheduling and cache machinery lives in ``repro.serve``; this module
is the launch-layer entry point.  ``ServingLoop`` picks a scheduler —
slot-level continuous batching (:class:`repro.serve.ContinuousScheduler`)
by default, falling back to the static-cohort loop for model families
without a paged decode path — and the CLI replays deterministic arrival
traces (uniform / poisson / bursty, fixed seeds) against it.

The legacy helpers (``Request``, ``sample``, ``pack_prompts``,
``mask_padded_cache``, ``build_serve_fns``) are re-exported from
``repro.serve`` so existing imports keep working.

Run as a script it serves a reduced model locally:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 4
"""
from __future__ import annotations

import argparse
import logging
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from ..core.config import ArchConfig
from ..models import build_model
from ..obs import metrics as obs_metrics
from ..obs.trace import get_tracer
from ..serve import (ARRIVALS, CohortScheduler, ContinuousScheduler,
                     Request, build_serve_fns, make_trace,
                     mask_padded_cache, pack_prompts, sample)

__all__ = ["Request", "ServingLoop", "build_serve_fns", "main",
           "mask_padded_cache", "pack_prompts", "sample"]

log = logging.getLogger("repro.serve")


class ServingLoop:
    """Launch-layer serving facade.

    ``scheduler="continuous"`` (the default) runs slot-level continuous
    batching over a paged KV arena; ``scheduler="cohort"`` runs the
    legacy static-cohort loop.  Families without a paged decode path
    (ssm / hybrid / encdec) fall back to cohort automatically.

    The scheduler's ``repro.obs.metrics.Registry`` is exposed as
    ``self.metrics`` (a private registry by default, so concurrent loops
    and tests never share counters)."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int,
                 rules=None, seed: int = 0, max_new: int = 64,
                 metrics: Optional[obs_metrics.Registry] = None,
                 scheduler: str = "continuous", block_len: int = 16,
                 max_seq: int = 1024, total_tokens: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 prefix_cache: bool = False):
        if scheduler not in ("continuous", "cohort"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if scheduler == "continuous" and build_model(cfg).decode_paged is None:
            log.info("family %s has no paged decode path; falling back to "
                     "cohort scheduling", cfg.family)
            scheduler = "cohort"
        if scheduler != "continuous" and (chunk_tokens or prefix_cache):
            log.info("chunked prefill / prefix caching need the continuous "
                     "scheduler; disabling both")
            chunk_tokens, prefix_cache = None, False
        if (chunk_tokens or prefix_cache) and int(cfg.n_patches or 0) > 0:
            log.info("family %s prepends patch rows during prefill, which "
                     "chunked prefill cannot align; disabling chunked "
                     "prefill / prefix caching", cfg.family)
            chunk_tokens, prefix_cache = None, False
        if scheduler == "continuous":
            self.scheduler = ContinuousScheduler(
                cfg, params, batch=batch, rules=rules, seed=seed,
                max_new=max_new, metrics=metrics, block_len=block_len,
                max_seq=max_seq, total_tokens=total_tokens,
                chunk_tokens=chunk_tokens, prefix_cache=prefix_cache)
        else:
            self.scheduler = CohortScheduler(
                cfg, params, batch=batch, rules=rules, seed=seed,
                max_new=max_new, metrics=metrics)
        self.cfg = cfg
        self.batch = batch
        self.scheduler_kind = scheduler
        self.chunk_tokens = chunk_tokens
        self.prefix_cache = prefix_cache

    @property
    def metrics(self) -> obs_metrics.Registry:
        return self.scheduler.metrics

    def run(self, requests: List[Request], temperature: float = 0.0,
            max_steps: int = 64) -> Dict[int, List[int]]:
        return self.scheduler.run(requests, temperature=temperature,
                                  max_steps=max_steps)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--ragged", action="store_true",
                    help="draw each prompt's length from [4, prompt-len] "
                         "to exercise the ragged/mixed-length path")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "cohort"],
                    help="slot-level continuous batching (default) or the "
                         "legacy static-cohort loop")
    ap.add_argument("--block-len", type=int, default=16,
                    help="paged KV cache block length (continuous only)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="split prefill into chunks of this many tokens "
                         "interleaved with decode steps (continuous only; "
                         "must be a multiple of --block-len)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-address full KV blocks and share cached "
                         "prompt prefixes across requests (implies chunked "
                         "prefill at 4 * --block-len unless --chunk-tokens "
                         "is given)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared-prefix traces: give arrival-trace prompts "
                         "a common random prefix of this many tokens")
    ap.add_argument("--prefix-group", type=int, default=0,
                    help="requests per shared prefix group (default: all "
                         "requests share one prefix)")
    ap.add_argument("--arrival", default="none",
                    choices=["none"] + list(ARRIVALS),
                    help="arrival trace: 'none' submits every request at "
                         "t=0; otherwise a deterministic virtual-step "
                         "trace at --rate requests/step")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="arrival rate in requests per virtual step")
    ap.add_argument("--burst", type=int, default=4,
                    help="burst size for --arrival bursty")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for prompts, arrivals and sampling")
    ap.add_argument("--tuning-registry", default=None,
                    help="autotuning registry JSON (default "
                         "./tuning_registry.json)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the serving metrics snapshot "
                         "(repro.obs.metrics) to PATH")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable span tracing; write the span JSONL to PATH")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    from ..tuning import apply_tuned_kernel_defaults
    apply_tuned_kernel_defaults(args.tuning_registry)
    if args.trace:
        get_tracer().enable()

    from ..configs import get_smoke_config
    from ..distributed.sharding import split_tree
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=args.batch, max_new=args.max_new,
                       seed=args.seed, scheduler=args.scheduler,
                       block_len=args.block_len,
                       max_seq=(args.prompt_len + args.prefix_len
                                + args.max_new + args.block_len),
                       chunk_tokens=args.chunk_tokens,
                       prefix_cache=args.prefix_cache)
    if args.arrival == "none":
        rng = np.random.default_rng(args.seed)
        lens = (rng.integers(4, args.prompt_len + 1, args.requests)
                if args.ragged else [args.prompt_len] * args.requests)
        prefix = (rng.integers(0, cfg.vocab,
                               (args.prefix_len,)).astype(np.int32)
                  if args.prefix_len > 0 else None)
        reqs = []
        for i in range(args.requests):
            p = rng.integers(0, cfg.vocab, (int(lens[i]),)).astype(np.int32)
            if prefix is not None:
                p = np.concatenate([prefix, p])
            reqs.append(Request(uid=i, prompt=p, max_new=args.max_new))
    else:
        lo = 4 if args.ragged else args.prompt_len
        reqs = make_trace(args.arrival, args.requests, vocab=cfg.vocab,
                          rate=args.rate, burst=args.burst, seed=args.seed,
                          prompt_lens=(lo, args.prompt_len),
                          max_new=(args.max_new, args.max_new),
                          prefix_len=args.prefix_len,
                          prefix_group=args.prefix_group)
    t0 = time.time()
    results = loop.run(reqs, max_steps=args.max_new)
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    snap = {(r["name"],): r for r in loop.metrics.snapshot()}
    ttft = snap.get(("serve.ttft_ms",), {})
    dec = snap.get(("serve.decode_ms",), {})
    occ = snap.get(("serve.batch_occupancy",), {})
    hit = ""
    cache = getattr(loop.scheduler, "cache", None)
    # the scheduler resolves a default chunk size when only
    # --prefix-cache is passed, so consult it rather than the CLI value
    if getattr(loop.scheduler, "chunk_tokens", None) is not None \
            and cache is not None:
        hit = f"; cache-hit ratio={cache.cache_hit_ratio:.2f}"
    print(f"[{loop.scheduler_kind}] served {len(results)} requests, "
          f"{total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s); "
          f"ttft p50={ttft.get('p50', 0):.0f}ms "
          f"p99={ttft.get('p99', 0):.0f}ms; "
          f"decode p50={dec.get('p50', 0):.1f}ms/tok "
          f"p99={dec.get('p99', 0):.1f}ms/tok; "
          f"occupancy mean={occ.get('mean', 0):.2f}{hit}")
    for r in sorted(reqs, key=lambda r: r.uid):
        print(f"  req {r.uid}: prompt={len(r.prompt)} arrival={r.arrival:.1f} "
              f"ttft={r.ttft_ms:.0f}ms total={r.total_ms:.0f}ms "
              f"toks={results[r.uid]}")
    if args.metrics_json:
        loop.metrics.save(args.metrics_json)
        print(f"wrote metrics snapshot to {args.metrics_json}")
    if args.trace:
        n = get_tracer().save_jsonl(args.trace)
        print(f"wrote {n} spans to {args.trace}")


if __name__ == "__main__":
    main()
