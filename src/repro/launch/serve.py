"""Serving driver: prefill + decode with continuous batched requests.

``build_serve_fns`` returns jitted (prefill, decode_step) closures; the
``ServingLoop`` packs requests into a fixed batch, prefills new sequences,
and steps the whole batch one token at a time — the standard static-batch
TPU serving shape (decode_32k / long_500k lower exactly this step).

Ragged prompts are LEFT-padded to the batch max and the pad slots are
masked out of the KV cache (``kpos = -1``, which ``attend_decode`` already
treats as "empty"), so a mixed-length batch decodes over real tokens only.
Left padding keeps every sequence's last prompt token in the final
position (the one ``prefill`` samples from), and the uniform position
shift it introduces is invariant under RoPE's relative-position attention;
only prefill-time attention still sees the pad keys, which is the standard
static-batch approximation.

Every request is measured (``repro.obs.metrics``): time-to-first-token,
per-token decode latency, batch occupancy, and queue depth — the metrics
the ROADMAP's latency-SLO / tokens-per-second serving scenarios gate on.

Run as a script it serves a reduced model locally:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 4
"""
from __future__ import annotations

import argparse
import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..distributed import sharding as shd
from ..models import build_model
from ..obs import metrics as obs_metrics
from ..obs.trace import get_tracer

log = logging.getLogger("repro.serve")


def build_serve_fns(model, rules=None, budget=None):
    def prefill(params, batch):
        with shd.use_rules(rules):
            return model.prefill(params, batch, budget=budget)

    def decode_step(params, state, tokens):
        with shd.use_rules(rules):
            return model.decode_step(params, state, tokens)

    return jax.jit(prefill), jax.jit(decode_step, donate_argnums=(1,))


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # filled in by the loop ---------------------------------------------------
    ttft_ms: Optional[float] = None     # submission -> first token (incl.
    #                                     queue wait)
    total_ms: Optional[float] = None    # submission -> request finished


def pack_prompts(active: List[Request], batch: int):
    """LEFT-pad ragged prompts into one (batch, max_len) int32 array.
    Returns (tokens, pads) where ``pads[i]`` is request i's pad count."""
    max_len = max(len(r.prompt) for r in active)
    tokens = np.zeros((batch, max_len), np.int32)
    pads = np.zeros((batch,), np.int32)
    for i, r in enumerate(active):
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        pads[i] = max_len - len(p)
        tokens[i, pads[i]:] = p
    return tokens, pads


def mask_padded_cache(state, pads: np.ndarray):
    """Rewrite the pad slots' cached positions to -1 so ``attend_decode``
    (which masks ``pos_cache < 0`` as empty) never attends them."""
    kpos = getattr(state, "kpos", None)
    if kpos is None or not np.any(pads):
        return state
    slot = jnp.arange(kpos.shape[-1], dtype=jnp.int32)
    pad_col = jnp.asarray(pads, jnp.int32)[None, :, None]
    masked = jnp.where(slot[None, None, :] < pad_col, -1, kpos)
    return state._replace(kpos=masked)


class ServingLoop:
    """Static-batch continuous serving: all sequences decode in lockstep;
    finished slots are refilled from the queue at the next prefill.

    ``metrics`` is a ``repro.obs.metrics.Registry`` (a private one by
    default, so concurrent loops and tests never share counters):

      serve.ttft_ms           histogram, per request
      serve.decode_ms         histogram, per decode step (per-token latency)
      serve.batch_occupancy   histogram, active/batch per prefill
      serve.queue_depth       gauge, requests still queued
      serve.requests_total    counter
      serve.tokens_total      counter
    """

    def __init__(self, cfg: ArchConfig, params, *, batch: int,
                 rules=None, seed: int = 0, max_new: int = 64,
                 metrics: Optional[obs_metrics.Registry] = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.model = build_model(cfg)
        self.max_new = max_new
        self._fns = {}          # prefill budget -> (prefill, decode)
        self.rules = rules
        self.key = jax.random.PRNGKey(seed)
        self.metrics = metrics if metrics is not None \
            else obs_metrics.Registry()

    def _get_fns(self, prompt_len: int):
        budget = prompt_len + self.max_new + 1
        if budget not in self._fns:
            self._fns[budget] = build_serve_fns(self.model, self.rules,
                                                budget=budget)
        return self._fns[budget]

    def run(self, requests: List[Request], temperature: float = 0.0,
            max_steps: int = 64) -> Dict[int, List[int]]:
        tracer = get_tracer()
        m = self.metrics
        ttft_h = m.histogram("serve.ttft_ms")
        dec_h = m.histogram("serve.decode_ms")
        occ_h = m.histogram("serve.batch_occupancy")
        qdepth = m.gauge("serve.queue_depth")
        req_c = m.counter("serve.requests_total")
        tok_c = m.counter("serve.tokens_total")

        t_submit = time.perf_counter()  # all requests enqueue at run start
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            active = queue[:self.batch]
            queue = queue[self.batch:]
            qdepth.set(len(queue))
            occ_h.observe(len(active) / self.batch)
            with tracer.span("serve.batch", n_active=len(active),
                             queued=len(queue)):
                prompts, pads = pack_prompts(active, self.batch)
                prefill_fn, decode_fn = self._get_fns(prompts.shape[1])
                batch = {"tokens": jnp.asarray(prompts)}
                if self.cfg.is_encdec:
                    batch["frames"] = jnp.zeros(
                        (self.batch, prompts.shape[1], self.cfg.d_model),
                        jnp.float32)
                if self.cfg.n_patches:
                    batch["patches"] = jnp.zeros(
                        (self.batch, self.cfg.n_patches, self.cfg.d_model),
                        jnp.float32)
                with tracer.span("serve.prefill",
                                 prompt_len=int(prompts.shape[1])):
                    logits, state = prefill_fn(self.params, batch)
                    state = mask_padded_cache(state, pads)
                    toks = sample(logits, self.key, temperature)[:, None]
                    toks = jax.block_until_ready(toks)
                t_first = time.perf_counter()
                for r in active:
                    r.ttft_ms = (t_first - t_submit) * 1e3
                    ttft_h.observe(r.ttft_ms)
                for step in range(max_steps):
                    for i, r in enumerate(active):
                        if not r.done and len(r.out_tokens) < r.max_new:
                            r.out_tokens.append(int(toks[i, 0]))
                        elif not r.done:
                            r.done = True
                    if all(r.done or len(r.out_tokens) >= r.max_new
                           for r in active):
                        break
                    self.key, sub = jax.random.split(self.key)
                    t0 = time.perf_counter()
                    with tracer.span("serve.decode_step", step=step):
                        logits, state = decode_fn(self.params, state,
                                                  toks.astype(jnp.int32))
                        toks = sample(logits, sub, temperature)[:, None]
                        toks = jax.block_until_ready(toks)
                    dec_h.observe((time.perf_counter() - t0) * 1e3)
                t_done = time.perf_counter()
                for r in active:
                    r.total_ms = (t_done - t_submit) * 1e3
                    results[r.uid] = r.out_tokens
                    req_c.inc()
                    tok_c.inc(len(r.out_tokens))
        qdepth.set(0)
        return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--ragged", action="store_true",
                    help="draw each prompt's length from [4, prompt-len] "
                         "to exercise the left-pad + mask path")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--tuning-registry", default=None,
                    help="autotuning registry JSON (default "
                         "./tuning_registry.json)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the serving metrics snapshot "
                         "(repro.obs.metrics) to PATH")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable span tracing; write the span JSONL to PATH")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    from ..tuning import apply_tuned_kernel_defaults
    apply_tuned_kernel_defaults(args.tuning_registry)
    if args.trace:
        get_tracer().enable()

    from ..configs import get_smoke_config
    from ..distributed.sharding import split_tree
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=args.batch, max_new=args.max_new)
    rng = np.random.default_rng(0)
    lens = (rng.integers(4, args.prompt_len + 1, args.requests)
            if args.ragged else [args.prompt_len] * args.requests)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        (int(lens[i]),)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    results = loop.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    snap = {(r["name"],): r for r in loop.metrics.snapshot()}
    ttft = snap.get(("serve.ttft_ms",), {})
    dec = snap.get(("serve.decode_ms",), {})
    occ = snap.get(("serve.batch_occupancy",), {})
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s); "
          f"ttft p50={ttft.get('p50', 0):.0f}ms "
          f"p99={ttft.get('p99', 0):.0f}ms; "
          f"decode p50={dec.get('p50', 0):.1f}ms/tok "
          f"p99={dec.get('p99', 0):.1f}ms/tok; "
          f"occupancy mean={occ.get('mean', 0):.2f}")
    for r in sorted(reqs, key=lambda r: r.uid):
        print(f"  req {r.uid}: prompt={len(r.prompt)} "
              f"ttft={r.ttft_ms:.0f}ms total={r.total_ms:.0f}ms "
              f"toks={results[r.uid]}")
    if args.metrics_json:
        loop.metrics.save(args.metrics_json)
        print(f"wrote metrics snapshot to {args.metrics_json}")
    if args.trace:
        n = get_tracer().save_jsonl(args.trace)
        print(f"wrote {n} spans to {args.trace}")


if __name__ == "__main__":
    main()
