"""Serving driver: prefill + decode with continuous batched requests.

``build_serve_fns`` returns jitted (prefill, decode_step) closures; the
``ServingLoop`` packs requests into a fixed batch, prefills new sequences,
and steps the whole batch one token at a time — the standard static-batch
TPU serving shape (decode_32k / long_500k lower exactly this step).

Run as a script it serves a reduced model locally:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 4
"""
from __future__ import annotations

import argparse
import logging
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..distributed import sharding as shd
from ..models import build_model

log = logging.getLogger("repro.serve")


def build_serve_fns(model, rules=None, budget=None):
    def prefill(params, batch):
        with shd.use_rules(rules):
            return model.prefill(params, batch, budget=budget)

    def decode_step(params, state, tokens):
        with shd.use_rules(rules):
            return model.decode_step(params, state, tokens)

    return jax.jit(prefill), jax.jit(decode_step, donate_argnums=(1,))


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServingLoop:
    """Static-batch continuous serving: all sequences decode in lockstep;
    finished slots are refilled from the queue at the next prefill."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int,
                 rules=None, seed: int = 0, max_new: int = 64):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.model = build_model(cfg)
        self.max_new = max_new
        self._fns = {}          # prefill budget -> (prefill, decode)
        self.rules = rules
        self.key = jax.random.PRNGKey(seed)

    def _get_fns(self, prompt_len: int):
        budget = prompt_len + self.max_new + 1
        if budget not in self._fns:
            self._fns[budget] = build_serve_fns(self.model, self.rules,
                                                budget=budget)
        return self._fns[budget]

    def run(self, requests: List[Request], temperature: float = 0.0,
            max_steps: int = 64) -> Dict[int, List[int]]:
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            active = queue[:self.batch]
            queue = queue[self.batch:]
            prompts = np.stack([r.prompt for r in active])
            pad = self.batch - len(active)
            if pad:
                prompts = np.concatenate(
                    [prompts, np.zeros((pad, prompts.shape[1]), np.int32)])
            prefill_fn, decode_fn = self._get_fns(prompts.shape[1])
            batch = {"tokens": jnp.asarray(prompts)}
            if self.cfg.is_encdec:
                batch["frames"] = jnp.zeros(
                    (self.batch, prompts.shape[1], self.cfg.d_model),
                    jnp.float32)
            if self.cfg.n_patches:
                batch["patches"] = jnp.zeros(
                    (self.batch, self.cfg.n_patches, self.cfg.d_model),
                    jnp.float32)
            logits, state = prefill_fn(self.params, batch)
            toks = sample(logits, self.key, temperature)[:, None]
            for step in range(max_steps):
                for i, r in enumerate(active):
                    if not r.done and len(r.out_tokens) < r.max_new:
                        r.out_tokens.append(int(toks[i, 0]))
                    elif not r.done:
                        r.done = True
                if all(r.done or len(r.out_tokens) >= r.max_new
                       for r in active):
                    break
                self.key, sub = jax.random.split(self.key)
                logits, state = decode_fn(self.params, state,
                                          toks.astype(jnp.int32))
                toks = sample(logits, sub, temperature)[:, None]
            for r in active:
                results[r.uid] = r.out_tokens
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--tuning-registry", default=None,
                    help="autotuning registry JSON (default "
                         "./tuning_registry.json)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    from ..tuning import apply_tuned_kernel_defaults
    apply_tuned_kernel_defaults(args.tuning_registry)

    from ..configs import get_smoke_config
    from ..distributed.sharding import split_tree
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))
    loop = ServingLoop(cfg, params, batch=args.batch)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        (args.prompt_len,)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    results = loop.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s)")
    for uid, toks in sorted(results.items()):
        print(f"  req {uid}: {toks}")


if __name__ == "__main__":
    main()
