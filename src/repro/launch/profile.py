"""Compile one dry-run cell and report its top HLO ops by weighted cost.

The library half of ``experiments/profile_cell.py``: build the jitted
train/prefill/decode computation for an (arch, shape) cell on the
production mesh, and rank its fused HLO ops by weighted bytes / flops /
wire (``core.hlo_cost``).  Exposed both as the original experiment script
and through ``python -m repro.obs.cli profile`` so HLO cost profiling and
runtime span tracing live behind one front door.

Requires enough host devices for the production mesh — call
``ensure_host_devices()`` (or export ``XLA_FLAGS`` yourself) BEFORE the
first jax import of the process.
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple

__all__ = ["ensure_host_devices", "compile_cell", "profile_report",
           "format_report"]

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int = 512) -> None:
    """Append the host-device-count flag to ``XLA_FLAGS`` without
    clobbering whatever the caller already set there.  A pre-existing
    device-count flag wins (the user asked for that topology).  Must run
    before jax initializes its backends."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVICE_FLAG in flags:
        return
    os.environ["XLA_FLAGS"] = f"{flags} {_DEVICE_FLAG}={n}".strip()


def compile_cell(arch: str, shape_name: str):
    """Lower + compile the cell's jitted computation; returns the compiled
    executable (``.as_text()`` is the optimized HLO)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..core import hardware
    from ..core.config import RunConfig, get_shape
    from ..distributed import sharding as shd
    from ..models import build_model
    from ..optim import adamw_init, moment_shardings
    from . import dryrun as D
    from . import train as T
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh()
    data = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.shape]))
    micro = max(1, shape.global_batch // data) if shape.mode == "train" else 1
    tp = mesh.shape.get("model", 1)
    state_gb = cfg.param_count() * 4 * 3.3 / tp / 2 ** 30
    fsdp = shape.mode == "train" \
        and state_gb > 0.5 * (hardware.HBM_BYTES / 2 ** 30)
    run = RunConfig(microbatches=micro, fsdp=fsdp)
    model = build_model(cfg)
    # jax >= 0.6 activates a mesh via jax.set_mesh; on 0.4.x the Mesh
    # object itself is the context manager
    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh is not None else mesh):
        rules = D.build_rules(mesh, cfg, shape, shape.mode, run)
        with shd.use_rules(rules):
            p_shapes, p_axes = D.abstract_params(model)
        if shape.mode in ("prefill", "decode"):
            p_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape,
                    jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
                p_shapes)
        p_sh = shd.tree_shardings_safe(p_axes, p_shapes, rules)
        specs = D.input_specs(cfg, shape)
        b_sh = D.batch_shardings(specs, rules)
        if shape.mode == "train":
            T.set_param_axes(p_axes)
            opt_shapes = jax.eval_shape(adamw_init, p_shapes)
            msh = moment_shardings(p_axes, jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), p_shapes),
                rules)
            opt_sh = type(opt_shapes)(step=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), m=msh, v=msh)
            comp = jax.jit(T.build_train_step(model, run, rules),
                           in_shardings=(p_sh, opt_sh, b_sh,
                                         jax.sharding.NamedSharding(
                                             mesh,
                                             jax.sharding.PartitionSpec())),
                           donate_argnums=(0, 1)).lower(
                p_shapes, opt_shapes, specs,
                jax.ShapeDtypeStruct((), jnp.int32)).compile()
        elif shape.mode == "prefill":
            def prefill_fn(params, batch):
                with shd.use_rules(rules):
                    return model.prefill(params, batch)
            comp = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh)).lower(
                p_shapes, specs).compile()
        else:
            st_shapes, st_sh = D.state_specs(cfg, shape, rules)

            def decode_fn(params, state, tokens):
                with shd.use_rules(rules):
                    return model.decode_step(params, state, tokens)
            comp = jax.jit(decode_fn,
                           in_shardings=(p_sh, st_sh, b_sh["tokens"]),
                           donate_argnums=(1,)).lower(
                p_shapes, st_shapes, specs["tokens"]).compile()
    return comp


def profile_report(arch: str, shape_name: str, k: int = 10
                   ) -> Dict[str, List[Tuple[float, float, str]]]:
    """Compile the cell and return {by_bytes, by_flops, by_wire} top-op
    lists, each entry (weighted_cost, weight, hlo_line)."""
    from ..core.hlo_cost import top_costs
    comp = compile_cell(arch, shape_name)
    by_bytes, by_flops, by_wire = top_costs(comp.as_text(), k=k)
    return {"by_bytes": by_bytes, "by_flops": by_flops, "by_wire": by_wire}


def format_report(arch: str, shape_name: str,
                  report: Dict[str, List[Tuple[float, float, str]]]) -> str:
    lines = [f"=== {arch} {shape_name}: top weighted fused-bytes ops ==="]
    for wb, w, line in report["by_bytes"]:
        lines.append(f"{wb:.3e} (w={w:.0f}) {line[:120]}")
    lines.append("=== top weighted flops ===")
    for wf, w, line in report["by_flops"][:6]:
        lines.append(f"{wf:.3e} (w={w:.0f}) {line[:120]}")
    lines.append("=== top weighted wire ===")
    for ww, w, line in report["by_wire"][:8]:
        lines.append(f"{ww:.3e} (w={w:.0f}) {line[:120]}")
    return "\n".join(lines)
