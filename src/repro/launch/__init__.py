"""Launchers: mesh construction, training driver, serving driver, dry-run.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — never import it
from tests or benchmarks; run it as ``python -m repro.launch.dryrun``.
"""
from . import mesh

__all__ = ["mesh"]
