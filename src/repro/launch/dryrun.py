import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production meshes and derive the three-term roofline.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices to build the
(2, 16, 16) multi-pod mesh.  Tests/benches never import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40-cell grid
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with the
memory analysis, loop-aware cost terms, collective schedule, and roofline
fractions (EXPERIMENTS.md SS Dry-run / SS Roofline read these)."""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import hardware, roofline
from ..core.config import ArchConfig, RunConfig, ShapeConfig, get_shape, SHAPES
from ..distributed import sharding as shd
from ..models import build_model
from ..models import transformer as tfm
from ..models import encdec as encdec_mod
from ..optim import adamw_init, moment_shardings
from . import train as train_mod
from .mesh import make_production_mesh, mesh_name

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

WHISPER_ENC_DECODE = 1500


# ---------------------------------------------------------------------------
# Rules per mode
# ---------------------------------------------------------------------------

def build_rules(mesh, cfg: ArchConfig, shape: ShapeConfig, mode: str,
                run: RunConfig):
    tp = mesh.shape.get("model", 1)
    shard_kv = cfg.n_kv_heads % tp == 0
    rules = shd.default_rules(mesh, shard_kv=shard_kv, fsdp=run.fsdp,
                              seq_shard=run.seq_shard)
    r = dict(rules.rules)
    data_axes = r["batch"]
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) \
        if data_axes else 1
    if shape.global_batch % dsize != 0 or shape.global_batch < dsize:
        r["batch"] = None          # e.g. long_500k's global_batch=1
    # KV-cache length axis: sharded over "model" for serving modes (the
    # 687 GB decode_32k caches do not fit any other way).  NOTE: "heads"
    # stays on "model" in every mode — head padding is derived from the
    # rules, so init and all apply modes must agree on it.
    r["kvlen"] = "model" if mode in ("prefill", "decode") else None
    return shd.ShardingRules(mesh, r)


# ---------------------------------------------------------------------------
# Abstract params / inputs
# ---------------------------------------------------------------------------

def abstract_params(model) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical-axes tree) without allocating."""
    box = {}

    def init_vals(key):
        vals, axes = shd.split_tree(model.init(key))
        box["axes"] = axes
        return vals

    shapes = jax.eval_shape(init_vals, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.is_encdec:
        n_dec = s // 2
        return {
            "tokens": jax.ShapeDtypeStruct((b, n_dec), i32),
            "labels": jax.ShapeDtypeStruct((b, n_dec), i32),
            "frames": jax.ShapeDtypeStruct((b, s - n_dec, cfg.d_model), f32),
        }
    n_text = s - (cfg.n_patches or 0)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, n_text), i32),
        "labels": jax.ShapeDtypeStruct((b, n_text), i32),
    }
    if cfg.n_patches:
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), f32)
    if shape.mode == "prefill":
        specs.pop("labels")
    return specs


def batch_shardings(specs: Dict[str, Any], rules) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.sharding(axes)
    return out


def state_specs(cfg: ArchConfig, shape: ShapeConfig, rules):
    """(ShapeDtypeStruct state, shardings) for decode cells."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        st = jax.eval_shape(
            lambda: encdec_mod.encdec_state_init(
                cfg, b, s, WHISPER_ENC_DECODE, jnp.dtype(cfg.dtype)))
        axes = encdec_mod.encdec_state_axes()
    else:
        st = jax.eval_shape(
            lambda: tfm.init_state(cfg, b, s, jnp.dtype(cfg.dtype)))
        axes = tfm.state_axes()
    shardings = jax.tree.map(
        lambda spec, ax: jax.sharding.NamedSharding(
            rules.mesh,
            shd.safe_spec(rules, _pad_axes(ax, len(spec.shape)), spec.shape)),
        st, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return st, shardings


def _pad_axes(ax, ndim):
    ax = tuple(ax)
    return ax + (None,) * (ndim - len(ax))


# ---------------------------------------------------------------------------
# Analytic useful-flops model
# ---------------------------------------------------------------------------

def useful_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6ND (train) / 2ND (inference) + attention term, whole job."""
    n = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        tokens = b                                # one token per sequence
        flops = 2.0 * n * tokens
        # decode attention reads the cache: 4 * L * H*hd * S_ctx per token
        if cfg.family not in ("ssm",):
            ctx = min(s, cfg.attn.window) if cfg.attn.window else s
            flops += 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim_ \
                * ctx * tokens
        return flops
    tokens = b * (s if not cfg.is_encdec else s // 2)
    mult = 6.0 if shape.mode == "train" else 2.0
    flops = mult * n * tokens
    if cfg.family != "ssm":
        ctx = min(s, cfg.attn.window) if cfg.attn.window else s
        # causal: half the S x S rectangle; x2 matmuls (qk, pv)
        att = 2.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim_ * s * ctx * b
        if not cfg.attn.window:
            att *= 0.5
        flops += att * (3.0 if shape.mode == "train" else 1.0)
    return flops


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not (
            cfg.attn.sub_quadratic or cfg.family == "ssm"):
        return ("full quadratic attention at 524k tokens — skipped per the "
                "assignment; see DESIGN.md §Arch-applicability")
    return None


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               run: Optional[RunConfig] = None,
               cfg: Optional[ArchConfig] = None) -> Dict[str, Any]:
    from ..configs import get_config
    cfg = cfg or get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mname,
        "mode": shape.mode, "n_chips": n_chips,
        "multi_pod": multi_pod,
    }
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    if run is None:
        data = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                            if a in mesh.shape]))
        micro = max(1, shape.global_batch // data) if shape.mode == "train" \
            else 1
        # auto-FSDP: fp32 params + grads + accumulator + moments live
        # per-chip; shard them over the data axes when TP alone won't fit
        tp = mesh.shape.get("model", 1)
        state_gb = cfg.param_count() * 4 * 3.3 / tp / 2 ** 30
        fsdp = shape.mode == "train" and state_gb > 0.5 * (
            hardware.HBM_BYTES / 2 ** 30)
        run = RunConfig(microbatches=micro, fsdp=fsdp,
                        grad_compression="bf16")
    rec["microbatches"] = run.microbatches
    rec["fsdp"] = run.fsdp

    model = build_model(cfg)
    t0 = time.time()
    # jax >= 0.6 activates a mesh via jax.set_mesh; on 0.4.x the Mesh
    # object itself is the context manager
    _set_mesh = getattr(jax, "set_mesh", None)
    with (_set_mesh(mesh) if _set_mesh is not None else mesh):
        mode = shape.mode
        rules = build_rules(mesh, cfg, shape, mode, run)
        with shd.use_rules(rules):
            # init under the same rules: head/vocab/expert padding is
            # derived from the rules and must match between init and apply
            p_shapes, p_axes = abstract_params(model)
        if mode in ("prefill", "decode"):
            # serving deployments hold bf16 weights
            p_shapes = jax.tree.map(
                lambda s_: jax.ShapeDtypeStruct(
                    s_.shape, jnp.bfloat16 if s_.dtype == jnp.float32
                    else s_.dtype), p_shapes)
        p_shardings = shd.tree_shardings_safe(p_axes, p_shapes, rules)
        specs = input_specs(cfg, shape)
        b_shardings = batch_shardings(specs, rules)

        if mode == "train":
            train_mod.set_param_axes(p_axes)
            opt_shapes = jax.eval_shape(adamw_init, p_shapes)
            mshard = moment_shardings(
                p_axes, jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                    p_shapes), rules)
            opt_shardings = type(opt_shapes)(
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                m=mshard, v=mshard)
            step_fn = train_mod.build_train_step(model, run, rules)
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shardings, opt_shardings, b_shardings,
                              jax.sharding.NamedSharding(
                                  mesh, jax.sharding.PartitionSpec())),
                donate_argnums=(0, 1),
            ).lower(p_shapes, opt_shapes, specs,
                    jax.ShapeDtypeStruct((), jnp.int32))
        elif mode == "prefill":
            def prefill_fn(params, batch):
                with shd.use_rules(rules):
                    return model.prefill(params, batch)
            lowered = jax.jit(
                prefill_fn, in_shardings=(p_shardings, b_shardings),
            ).lower(p_shapes, specs)
        else:  # decode
            st_shapes, st_shardings = state_specs(cfg, shape, rules)
            def decode_fn(params, state, tokens):
                with shd.use_rules(rules):
                    return model.decode_step(params, state, tokens)
            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_shardings, st_shardings,
                              b_shardings["tokens"]),
                donate_argnums=(1,),
            ).lower(p_shapes, st_shapes, specs["tokens"])

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rep = roofline.analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mname,
        n_chips=n_chips, model_flops_total=useful_flops(cfg, shape),
        memory=mem)
    # train/decode donate their big inputs: outputs alias args, so the peak
    # is max(args, out) + temps; prefill creates a fresh state (no aliasing)
    if mode in ("train", "decode"):
        peak = max(rep.arg_bytes, rep.out_bytes) + rep.temp_bytes
    else:
        peak = rep.arg_bytes + rep.out_bytes + rep.temp_bytes
    rec.update(
        status="ok",
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        hbm_per_chip_gb=round(peak / 2 ** 30, 3),
        arg_bytes=rep.arg_bytes, temp_bytes=rep.temp_bytes,
        out_bytes=rep.out_bytes,
        fits_hbm=peak <= hardware.HBM_BYTES,
        hlo_flops=rep.hlo_flops, hlo_bytes=rep.hlo_bytes,
        hlo_bytes_upper=rep.hlo_bytes_upper,
        collective_wire_bytes=rep.collective_wire_bytes,
        collective_counts=rep.collective_counts,
        collective_bytes_by_kind=rep.collective_bytes_by_kind,
        model_flops_per_chip=rep.model_flops,
        t_compute=rep.t_compute, t_memory=rep.t_memory,
        t_collective=rep.t_collective, bottleneck=rep.bottleneck,
        useful_flops_ratio=rep.useful_flops_ratio,
        roofline_fraction=rep.roofline_fraction,
    )
    return rec


def save_record(rec: Dict[str, Any], out_dir: str = OUT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True, default=str)
    return os.path.join(out_dir, name)


def summarize(rec: Dict[str, Any]) -> str:
    if rec.get("status") == "skipped":
        return (f"{rec['arch']:>20s} {rec['shape']:<12s} {rec['mesh']:<9s} "
                f"SKIPPED: {rec['reason'][:60]}")
    if rec.get("status") != "ok":
        return (f"{rec['arch']:>20s} {rec['shape']:<12s} {rec['mesh']:<9s} "
                f"FAILED: {rec.get('error', '?')[:80]}")
    return (f"{rec['arch']:>20s} {rec['shape']:<12s} {rec['mesh']:<9s} "
            f"hbm={rec['hbm_per_chip_gb']:6.2f}G "
            f"tc={rec['t_compute']*1e3:8.2f}ms "
            f"tm={rec['t_memory']*1e3:8.2f}ms "
            f"tx={rec['t_collective']*1e3:8.2f}ms "
            f"{rec['bottleneck']:<10s} "
            f"useful={rec['useful_flops_ratio']*100:5.1f}% "
            f"roof={rec['roofline_fraction']*100:5.1f}% "
            f"[{rec['compile_s']:.0f}s]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    from ..configs import ARCH_NAMES
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp)
                except Exception as e:          # record, keep going
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "failed", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                save_record(rec, args.out)
                print(summarize(rec), flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
