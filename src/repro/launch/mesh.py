"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run forces 512 host devices via XLA_FLAGS before any jax import, while
tests and benches must see the single real device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) = ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return _make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever devices exist, data-major (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
