"""Autotuning subsystem: empirical async-strategy search with a persistent
results registry.

The paper's central finding is that asynchronous data movement only pays in
specific regimes; this package turns the repo's per-kernel constants
(strategy, ring depth, tile shape) from guesses into *searched, measured,
cached and reused* decisions:

  SearchSpace / TuningTask   enumerate candidates, prune analytically
  Autotuner                  time survivors (warmup/repeat/outliers)
  Registry                   schema-versioned JSON cache with provenance
  tuned(...)                 best-config lookup for a call site
  apply_registry_defaults()  install winners as kernel defaults (serve/train)

CLI:  PYTHONPATH=src python -m repro.tuning.cli tune --kernel stream
"""
from .registry import (Measurement, Registry, SchemaMismatch, TuningRecord,
                       SCHEMA_VERSION, default_registry_path, make_key)
from .search_space import (Candidate, KernelSpec, SearchSpace, TuningTask,
                           KERNELS, SPECS, default_task, issue_ahead,
                           predict_time, strategy_depth_waits)
from .autotuner import (Autotuner, TimingStats, apply_registry_defaults,
                        apply_tuned_kernel_defaults, decode_config,
                        time_callable, tune_kernel, tuned)

__all__ = [
    "Autotuner", "Candidate", "KernelSpec", "KERNELS", "Measurement",
    "Registry", "SCHEMA_VERSION", "SchemaMismatch", "SearchSpace", "SPECS",
    "TimingStats", "TuningRecord", "TuningTask", "apply_registry_defaults",
    "apply_tuned_kernel_defaults", "decode_config", "default_registry_path",
    "default_task", "issue_ahead", "make_key", "predict_time",
    "strategy_depth_waits", "time_callable", "tune_kernel", "tuned",
]
