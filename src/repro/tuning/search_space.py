"""Candidate enumeration + analytic pruning for the autotuner.

Per kernel we enumerate (strategy x ring depth x tile shape) candidates,
attach an analytic execution-time prediction from the roofline model
(``core.balance`` / ``core.hardware`` peaks, with the per-strategy overlap
terms from the paper's Fig. 3 analysis), and drop candidates that are
*obviously dominated* before any empirical timing:

  * infeasible: tile shapes that do not divide the problem, or whose VMEM
    footprint exceeds the chip's scratch budget;
  * dominated: predicted time worse than ``keep_ratio`` x the best
    prediction (the paper's expectation model is only trusted for coarse
    ordering — the empirical pass decides among the survivors).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import hardware
from ..core.async_pipeline import Strategy
from ..kernels import ops
from ..kernels.matmul import matmul_vmem_bytes
from ..kernels.stream import stream_flops_bytes

#: keep candidates predicted within this factor of the analytic best
DEFAULT_KEEP_RATIO = 2.0

#: per-tile DMA issue overhead used by the strategy model (seconds)
ISSUE_S = 1e-6

#: DMA latency (seconds) before a copy's first byte lands — the 2208.11174
#: Ampere-microbenchmark-style constant the pipeline model amortises against.
#: With issue-ahead A, sustained DMA bandwidth is capped by Little's law at
#: A * t_tile / (latency + t_tile) of peak: a deeper wait group keeps more
#: copies in flight and recovers bandwidth, at the cost of a longer fill.
DMA_LATENCY_S = 2e-6

#: TMA cost terms (Hopper microbenchmark papers, arXiv:2402.13499 /
#: 2501.12084): a single bulk tensor copy has *higher* per-transaction
#: latency than a cp.async group (descriptor parse + mbarrier arrive), but
#: it is issued once by one producer — the per-tile issue overhead is a
#: fraction of the per-copy ISSUE_S a cp.async-style loop pays — and a bulk
#: 2D transaction sustains near-peak HBM bandwidth once the ring covers the
#: latency.
TMA_LATENCY_S = 3e-6
TMA_ISSUE_S = 0.25e-6
TMA_BULK_BW_FRAC = 0.93


def issue_ahead(depth: int, wait_group: Optional[int]) -> int:
    """Issue-ahead distance A for a (depth, wait_group) pipeline shape:
    at most A copies are in flight while tile i computes."""
    d = max(depth, 2)
    return d - 1 if wait_group is None else max(0, min(wait_group, d - 1))


def predict_time(strategy: Strategy, flops: float, nbytes: float, *,
                 depth: int, n_tiles: int,
                 wait_group: Optional[int] = None,
                 chip: Optional[hardware.Chip] = None) -> float:
    """Analytic execution-time model (seconds) for one strategy.

    sync:            t_m * 1.5 + t_c   (staging re-pass through VMEM)
    register_bypass: t_m + t_c         (no overlap, no staging)
    overlap:         max(t_m / bw_frac, t_c) + ring fill, where
                     bw_frac = min(1, A*t_tile / (latency + t_tile)) is the
                     Little's-law bandwidth fraction an issue-ahead of A
                     copies sustains — this is what makes depth an interior
                     optimum: deeper rings recover bandwidth until bw_frac
                     saturates at 1, after which the longer fill only hurts
    drop_off:        same pipeline law at chunk granularity (tile/4), plus
                     chunked issue overhead
    tma:             bulk-copy pipeline at the deepest issue-ahead
                     (depth - 1; the mbarrier has no wait-group axis):
                     max(t_m / bw_frac, t_c) + fill, with the Little's-law
                     fraction against the *higher* TMA per-transaction
                     latency, capped at TMA_BULK_BW_FRAC of peak, and the
                     much smaller single-producer descriptor issue cost
    """
    chip = chip or hardware.TARGET
    t_c = flops / (chip.tflops_f32 * 1e12)
    t_m = nbytes / (chip.mem_bw_gbs * 1e9)
    n_tiles = max(n_tiles, 1)
    issue = ISSUE_S * n_tiles
    if strategy == Strategy.SYNC:
        return t_m * 1.5 + t_c + issue
    if strategy == Strategy.REGISTER_BYPASS:
        return t_m + t_c + issue
    if strategy == Strategy.TMA:
        ahead = max(depth, 2) - 1       # mbarrier: always the deepest ahead
        t_tile = t_m / n_tiles
        bw_frac = TMA_BULK_BW_FRAC * min(
            1.0, ahead * t_tile / (TMA_LATENCY_S + t_tile))
        fill = ahead * t_tile + TMA_LATENCY_S
        return max(t_m / bw_frac, t_c) + fill + TMA_ISSUE_S * n_tiles
    ahead = issue_ahead(depth, wait_group)
    t_tile = t_m / n_tiles
    if strategy == Strategy.OVERLAP:
        if ahead == 0:          # degenerate wait_group=0: no overlap at all
            return t_m + t_c + issue
        bw_frac = min(1.0, ahead * t_tile / (DMA_LATENCY_S + t_tile))
        fill = ahead * t_tile + DMA_LATENCY_S
        return max(t_m / bw_frac, t_c) + fill + issue
    # DROP_OFF: chunk-granularity pipeline, more per-chunk issue overhead
    t_chunk = t_tile / 4
    a_eff = max(ahead, 1)
    bw_frac = min(1.0, a_eff * t_chunk / (DMA_LATENCY_S + t_chunk))
    fill = t_chunk + DMA_LATENCY_S
    return max(t_m / bw_frac, t_c) + fill + 4 * issue


@dataclass
class Candidate:
    """One point of a kernel's search space, with its analytic position."""
    config: Dict[str, Any]
    predicted_us: float = 0.0
    vmem_bytes: int = 0
    feasible: bool = True
    why_pruned: str = ""

    @property
    def strategy(self) -> Strategy:
        return self.config["strategy"]


# ---------------------------------------------------------------------------
# Per-kernel specs: how to build inputs, call the kernel, enumerate tiles,
# and estimate flops/bytes/VMEM for a candidate.
# ---------------------------------------------------------------------------

STRATEGIES: Tuple[Strategy, ...] = tuple(Strategy)
DEPTHS: Tuple[int, ...] = (2, 3, 4)


def strategy_depths(strategy: Strategy) -> Tuple[int, ...]:
    """Ring depths worth searching: SYNC and REGISTER_BYPASS are
    single-buffered (emit ignores depth), so depth variants would be
    duplicate candidates measured twice."""
    if strategy in (Strategy.SYNC, Strategy.REGISTER_BYPASS):
        return (2,)
    return DEPTHS


def strategy_depth_waits(strategy: Strategy
                         ) -> Tuple[Tuple[int, Optional[int]], ...]:
    """(depth, wait_group) pipeline shapes worth searching per strategy.

    ``wait_group=None`` is the deepest safe issue-ahead (depth - 1).  At
    depth 2 that is the only distinct shape (wait_group 1 == None); deeper
    rings add a shallow-wait variant (wait for tile i with only 1 copy in
    flight) — the ``cp.async.wait_group N`` axis where buffering and
    synchronisation depth decouple.

    TMA has no wait-group axis at all: the per-slot mbarrier tracks every
    outstanding byte of its slot, so the only shape parameter is the ring
    depth (issue-ahead is always depth - 1)."""
    if strategy in (Strategy.SYNC, Strategy.REGISTER_BYPASS):
        return ((2, None),)
    if strategy is Strategy.TMA:
        return tuple((d, None) for d in strategy_depths(strategy))
    out = []
    for d in strategy_depths(strategy):
        out.append((d, None))
        if d > 2:
            out.append((d, 1))
    return tuple(out)


def _strategy_depth_pairs():
    return [(s, d, w) for s in STRATEGIES
            for d, w in strategy_depth_waits(s)]


def _dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


@dataclass
class KernelSpec:
    name: str
    default_shape: Tuple[int, ...]
    make_args: Callable[[Tuple[int, ...], Any], Tuple]
    call: Callable[..., Any]          # call(args, config, interpret)
    enumerate_configs: Callable[[Tuple[int, ...]], List[Dict[str, Any]]]
    flops_bytes: Callable[[Tuple[int, ...], Any, Dict[str, Any]],
                          Tuple[float, float]]
    n_tiles: Callable[[Tuple[int, ...], Dict[str, Any]], int]
    vmem_bytes: Callable[[Tuple[int, ...], Any, Dict[str, Any]], int]


def _uniform(shape, dtype, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape,
                              jnp.dtype(dtype))


# -- stream -----------------------------------------------------------------

STREAM_ITERS = 4          # fixed workload intensity for tuning runs


def _stream_configs(shape):
    rows, _ = shape
    out = []
    for (s, depth, wg), tr, nt in itertools.product(
            _strategy_depth_pairs(), (8, 16, 32), (2, 4, 8)):
        if rows % (tr * nt):
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        out_depth=2, tile_rows=tr, n_tiles=nt))
    return out


def _stream_vmem(shape, dtype, cfg):
    _, width = shape
    isz = _dtype_bytes(dtype)
    tile = cfg["tile_rows"] * width * isz
    d = 1 if cfg["strategy"] in (Strategy.SYNC, Strategy.REGISTER_BYPASS) \
        else cfg["depth"]
    stage = tile if cfg["strategy"] == Strategy.SYNC else 0
    out_d = cfg.get("out_depth", 2)
    return d * tile + out_d * tile + stage      # in ring + out ring + staging


STREAM = KernelSpec(
    name="stream",
    default_shape=(512, 256),
    make_args=lambda shape, dtype: (_uniform(shape, dtype),),
    call=lambda args, cfg, interp: ops.stream(
        args[0], iters=STREAM_ITERS, interpret=interp, **cfg),
    enumerate_configs=_stream_configs,
    flops_bytes=lambda shape, dtype, cfg: stream_flops_bytes(
        shape, STREAM_ITERS, _dtype_bytes(dtype)),
    n_tiles=lambda shape, cfg: cfg["n_tiles"],
    vmem_bytes=_stream_vmem,
)


# -- matmul -----------------------------------------------------------------

def _matmul_configs(shape):
    m, k, n = shape
    out = []
    for (s, depth, wg), bm, bk, bn in itertools.product(
            _strategy_depth_pairs(), (128, 256), (128, 256), (128, 256)):
        if m % bm or k % bk or n % bn:
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        bm=bm, bk=bk, bn=bn))
    return out


def _matmul_flops_bytes(shape, dtype, cfg):
    m, k, n = shape
    isz = _dtype_bytes(dtype)
    flops = 2.0 * m * k * n
    # A streamed once per N-block, B once per M-block, fp32 C written once
    nbytes = (m * k * (n // cfg["bn"]) + k * n * (m // cfg["bm"])) * isz \
        + m * n * 4
    return flops, nbytes


MATMUL = KernelSpec(
    name="matmul",
    default_shape=(256, 256, 256),
    make_args=lambda shape, dtype: (
        _uniform((shape[0], shape[1]), dtype, 0),
        _uniform((shape[1], shape[2]), dtype, 1)),
    call=lambda args, cfg, interp: ops.matmul(
        args[0], args[1], interpret=interp, **cfg),
    enumerate_configs=_matmul_configs,
    flops_bytes=_matmul_flops_bytes,
    n_tiles=lambda shape, cfg: shape[1] // cfg["bk"],
    vmem_bytes=lambda shape, dtype, cfg: matmul_vmem_bytes(
        cfg["strategy"], cfg["bm"], cfg["bk"], cfg["bn"], cfg["depth"],
        _dtype_bytes(dtype)),
)


# -- hotspot ----------------------------------------------------------------

def _hotspot_configs(shape):
    rows, _ = shape
    out = []
    for (s, depth, wg), tr in itertools.product(_strategy_depth_pairs(),
                                                (8, 16, 32)):
        if rows % tr:
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        out_depth=2, tile_rows=tr))
    return out


def _hotspot_vmem(shape, dtype, cfg):
    _, cols = shape
    isz = _dtype_bytes(dtype)
    t_tile = (cfg["tile_rows"] + 2) * (cols + 2) * isz
    p_tile = cfg["tile_rows"] * cols * isz
    d = 1 if cfg["strategy"] in (Strategy.SYNC, Strategy.REGISTER_BYPASS) \
        else cfg["depth"]
    stage = (t_tile + p_tile) if cfg["strategy"] == Strategy.SYNC else 0
    return d * (t_tile + p_tile) + cfg.get("out_depth", 2) * p_tile + stage


HOTSPOT = KernelSpec(
    name="hotspot",
    default_shape=(256, 256),
    make_args=lambda shape, dtype: (_uniform(shape, dtype, 0),
                                    _uniform(shape, dtype, 1)),
    call=lambda args, cfg, interp: ops.hotspot(
        args[0], args[1], iters=1, interpret=interp, **cfg),
    enumerate_configs=_hotspot_configs,
    flops_bytes=lambda shape, dtype, cfg: (
        10.0 * shape[0] * shape[1],
        3.0 * shape[0] * shape[1] * _dtype_bytes(dtype)),
    n_tiles=lambda shape, cfg: max(shape[0] // cfg["tile_rows"], 1),
    vmem_bytes=_hotspot_vmem,
)


# -- lud --------------------------------------------------------------------

def _lud_configs(shape):
    n = shape[0]
    out = []
    for (s, depth, wg), bs in itertools.product(_strategy_depth_pairs(),
                                                (16, 32, 64)):
        if n % bs or bs >= n:
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        out_depth=2, bs=bs))
    return out


LUD = KernelSpec(
    name="lud",
    default_shape=(64,),     # interpret-mode compile cost grows fast with n
    make_args=lambda shape, dtype: (
        (_uniform((shape[0], shape[0]), dtype)
         + shape[0] * jnp.eye(shape[0], dtype=jnp.dtype(dtype))),),
    call=lambda args, cfg, interp: ops.lud(args[0], interpret=interp, **cfg),
    enumerate_configs=_lud_configs,
    flops_bytes=lambda shape, dtype, cfg: (
        (2.0 / 3.0) * shape[0] ** 3,
        2.0 * shape[0] ** 3 / (3.0 * cfg["bs"]) * _dtype_bytes(dtype)),
    n_tiles=lambda shape, cfg: max(shape[0] // cfg["bs"] - 1, 1),
    vmem_bytes=lambda shape, dtype, cfg: (
        (2 + (1 if cfg["strategy"] in (Strategy.SYNC,
                                       Strategy.REGISTER_BYPASS)
          else cfg["depth"]) * 2 + cfg.get("out_depth", 2) + 2)
        * 128 * cfg["bs"] * _dtype_bytes(dtype)),
)


# -- nw ---------------------------------------------------------------------

def _nw_configs(shape):
    n = shape[0]
    out = []
    for (s, depth, wg), tr in itertools.product(_strategy_depth_pairs(),
                                                (4, 8, 16)):
        if n % tr:
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        out_depth=2, tile_rows=tr))
    return out


def _nw_width(n):
    return ((n + 1 + 127) // 128) * 128


NW = KernelSpec(
    name="nw",
    default_shape=(128,),
    make_args=lambda shape, dtype: (
        jax.random.randint(jax.random.PRNGKey(0),
                           (shape[0], shape[0]), -3, 4).astype(jnp.float32),),
    call=lambda args, cfg, interp: ops.nw(
        args[0], penalty=10, interpret=interp, **cfg),
    enumerate_configs=_nw_configs,
    flops_bytes=lambda shape, dtype, cfg: (
        4.0 * shape[0] * _nw_width(shape[0]),
        2.0 * shape[0] * _nw_width(shape[0]) * 4),
    n_tiles=lambda shape, cfg: max(shape[0] // cfg["tile_rows"], 1),
    vmem_bytes=lambda shape, dtype, cfg: (
        ((1 if cfg["strategy"] in (Strategy.SYNC, Strategy.REGISTER_BYPASS)
          else cfg["depth"]) + 1 + cfg.get("out_depth", 2) +
         (1 if cfg["strategy"] == Strategy.SYNC else 0))
        * cfg["tile_rows"] * _nw_width(shape[0]) * 4),
)


# -- pathfinder -------------------------------------------------------------

def _pathfinder_configs(shape):
    rows, _ = shape
    out = []
    for (s, depth, wg), tr in itertools.product(_strategy_depth_pairs(),
                                                (4, 8, 16)):
        if (rows - 1) % tr:
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        tile_rows=tr))
    return out


PATHFINDER = KernelSpec(
    name="pathfinder",
    default_shape=(129, 256),
    make_args=lambda shape, dtype: (
        jax.random.randint(jax.random.PRNGKey(0), shape, 0, 10, jnp.int32),),
    call=lambda args, cfg, interp: ops.pathfinder(
        args[0], interpret=interp, **cfg),
    enumerate_configs=_pathfinder_configs,
    flops_bytes=lambda shape, dtype, cfg: (
        3.0 * shape[0] * shape[1], float(shape[0] * shape[1] * 4)),
    n_tiles=lambda shape, cfg: max((shape[0] - 1) // cfg["tile_rows"], 1),
    vmem_bytes=lambda shape, dtype, cfg: (
        ((1 if cfg["strategy"] in (Strategy.SYNC, Strategy.REGISTER_BYPASS)
          else cfg["depth"]) + 2 +
         (1 if cfg["strategy"] == Strategy.SYNC else 0))
        * cfg["tile_rows"] * shape[1] * 4),
)


# -- flash attention --------------------------------------------------------

def _flash_configs(shape):
    _, s_len, _ = shape
    out = []
    for (s, depth, wg), bq, bk in itertools.product(
            _strategy_depth_pairs(), (128, 256), (128, 256)):
        if s_len % bq or s_len % bk:
            continue
        out.append(dict(strategy=s, depth=depth, wait_group=wg,
                        bq=bq, bk=bk))
    return out


def _flash_flops_bytes(shape, dtype, cfg):
    h, s, d = shape
    isz = _dtype_bytes(dtype)
    flops = 2.0 * 2.0 * h * s * s * d * 0.5          # 2 matmuls, causal half
    nbytes = h * (s // cfg["bq"]) * 2 * s * d * isz * 0.5 \
        + h * s * d * (isz + 4)
    return flops, nbytes


FLASH = KernelSpec(
    name="flash_attention",
    default_shape=(2, 256, 64),
    make_args=lambda shape, dtype: tuple(
        jax.random.normal(jax.random.PRNGKey(i), shape, jnp.dtype(dtype))
        for i in range(3)),
    call=lambda args, cfg, interp: ops.flash_attention(
        args[0], args[1], args[2], causal=True, interpret=interp, **cfg),
    enumerate_configs=_flash_configs,
    flops_bytes=_flash_flops_bytes,
    n_tiles=lambda shape, cfg: max(shape[1] // cfg["bk"], 1),
    vmem_bytes=lambda shape, dtype, cfg: (
        ((1 if cfg["strategy"] in (Strategy.SYNC, Strategy.REGISTER_BYPASS)
          else cfg["depth"]) * 2 * cfg["bk"] * shape[2]
         * _dtype_bytes(dtype))
        + cfg["bq"] * shape[2] * (_dtype_bytes(dtype) + 4) + cfg["bq"] * 8),
)


SPECS: Dict[str, KernelSpec] = {
    s.name: s for s in
    (STREAM, MATMUL, HOTSPOT, LUD, NW, PATHFINDER, FLASH)
}

KERNELS: Tuple[str, ...] = tuple(SPECS)


# ---------------------------------------------------------------------------
# SearchSpace + TuningTask
# ---------------------------------------------------------------------------

class SearchSpace:
    """All candidates for (kernel, shape, dtype) with analytic annotations."""

    def __init__(self, kernel: str, shape: Sequence[int],
                 dtype: str = "float32",
                 chip: Optional[hardware.Chip] = None,
                 vmem_limit: Optional[int] = None):
        if kernel not in SPECS:
            raise KeyError(f"unknown kernel {kernel!r}; known: {KERNELS}")
        self.spec = SPECS[kernel]
        self.kernel = kernel
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype
        self.chip = chip or hardware.TARGET
        if vmem_limit is not None:
            self.vmem_limit = vmem_limit
        elif self.chip.vmem_mb:
            self.vmem_limit = int(self.chip.vmem_mb * 2 ** 20)
        else:
            self.vmem_limit = hardware.VMEM_BYTES

    def annotate(self, config: Dict[str, Any]) -> Candidate:
        flops, nbytes = self.spec.flops_bytes(self.shape, self.dtype, config)
        t = predict_time(config["strategy"], flops, nbytes,
                         depth=config["depth"],
                         n_tiles=self.spec.n_tiles(self.shape, config),
                         wait_group=config.get("wait_group"),
                         chip=self.chip)
        vmem = int(self.spec.vmem_bytes(self.shape, self.dtype, config))
        return Candidate(config=dict(config), predicted_us=t * 1e6,
                         vmem_bytes=vmem)

    def candidates(self) -> List[Candidate]:
        return [self.annotate(c)
                for c in self.spec.enumerate_configs(self.shape)]

    def pruned(self, keep_ratio: float = DEFAULT_KEEP_RATIO
               ) -> Tuple[List[Candidate], List[Candidate]]:
        """(survivors, dropped).  Drops VMEM-infeasible candidates, pipeline
        shapes past analytic break-even (issue-ahead covering the whole tile
        stream — the ring fill then costs the entire memory time up front,
        so the async pipeline provably cannot beat the synchronous bound),
        and candidates analytically dominated by more than ``keep_ratio``."""
        cands = self.candidates()
        for c in cands:
            if c.vmem_bytes > self.vmem_limit:
                c.feasible = False
                c.why_pruned = (f"vmem {c.vmem_bytes} > "
                                f"limit {self.vmem_limit}")
        for c in cands:
            if not c.feasible:
                continue
            if c.config["strategy"] in (Strategy.OVERLAP, Strategy.DROP_OFF,
                                        Strategy.TMA):
                ahead = issue_ahead(c.config["depth"],
                                    c.config.get("wait_group"))
                n = max(self.spec.n_tiles(self.shape, c.config), 1)
                if ahead >= n:
                    c.feasible = False
                    c.why_pruned = (
                        f"break-even: issue-ahead {ahead} >= n_tiles {n}; "
                        "ring fill spans the whole stream, cannot beat sync")
        feasible = [c for c in cands if c.feasible]
        if feasible:
            best = min(c.predicted_us for c in feasible)
            for c in feasible:
                if c.predicted_us > keep_ratio * best:
                    c.feasible = False
                    c.why_pruned = (f"predicted {c.predicted_us:.1f}us > "
                                    f"{keep_ratio:g}x best {best:.1f}us")
        survivors = [c for c in cands if c.feasible]
        dropped = [c for c in cands if not c.feasible]
        return survivors, dropped


@dataclass
class TuningTask:
    """One tunable cell: a kernel at a concrete shape/dtype on a chip."""
    kernel: str
    shape: Tuple[int, ...]
    dtype: str = "float32"
    chip: str = hardware.TARGET.name
    interpret: bool = True
    keep_ratio: float = DEFAULT_KEEP_RATIO
    space: SearchSpace = field(init=False)

    def __post_init__(self):
        self.shape = tuple(int(s) for s in self.shape)
        self.space = SearchSpace(self.kernel, self.shape, self.dtype,
                                 chip=hardware.get_chip(self.chip))

    def make_args(self) -> Tuple:
        return self.space.spec.make_args(self.shape, self.dtype)

    def call(self, args: Tuple, config: Dict[str, Any]):
        return self.space.spec.call(args, config, self.interpret)


def default_task(kernel: str, *, shape: Optional[Sequence[int]] = None,
                 dtype: str = "float32", interpret: bool = True,
                 chip: Optional[str] = None) -> TuningTask:
    spec = SPECS[kernel]
    return TuningTask(kernel=kernel,
                      shape=tuple(shape or spec.default_shape), dtype=dtype,
                      chip=chip or hardware.TARGET.name, interpret=interpret)
