"""Persistent autotuning results registry.

One JSON file holds every tuning record this host has produced, keyed by
``kernel|shape|dtype|chip``.  Records carry full measurement provenance
(every candidate's timings, the analytic prediction, prune statistics), not
just the winning config, so the paper's expectation-vs-measurement analysis
can be replayed from the registry alone.

The file is schema-versioned: a registry written by an incompatible version
is *ignored* (with a warning) rather than misread — tuning is a cache, so
the safe failure mode is re-measurement, never a wrong config.
"""
from __future__ import annotations

import json
import logging
import os
import tempfile
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

log = logging.getLogger("repro.tuning")

SCHEMA_VERSION = 2      # v2: measurement mode (interpret/compiled) in keys

#: Environment override for the default registry location.
REGISTRY_ENV = "REPRO_TUNING_REGISTRY"
DEFAULT_REGISTRY = "tuning_registry.json"


def default_registry_path() -> str:
    return os.environ.get(REGISTRY_ENV, DEFAULT_REGISTRY)


def make_key(kernel: str, shape: Sequence[int], dtype: str, chip: str,
             interpret: bool = True) -> str:
    """interpret- and compiled-mode timings are not comparable, so the mode
    is part of the cell identity — a TPU tune can never be clobbered by a
    CPU interpreter run of the same (kernel, shape, dtype, chip)."""
    return "|".join([kernel, "x".join(str(int(s)) for s in shape),
                     str(dtype), chip,
                     "interpret" if interpret else "compiled"])


@dataclass
class Measurement:
    """One empirically-timed candidate (or its failure)."""
    config: Dict[str, Any]
    us_median: float = 0.0
    us_mean: float = 0.0
    us_min: float = 0.0
    us_std: float = 0.0
    n_trials: int = 0
    n_outliers: int = 0
    predicted_us: float = 0.0
    error: Optional[str] = None


@dataclass
class TuningRecord:
    """Everything the autotuner learned about one (kernel, shape, dtype,
    chip) cell: the winner plus full provenance."""
    kernel: str
    shape: List[int]
    dtype: str
    chip: str
    best: Dict[str, Any]
    best_us: float
    default_us: float = 0.0            # the hard-coded default's time
    speedup_vs_default: float = 0.0
    measurements: List[Measurement] = field(default_factory=list)
    n_candidates: int = 0
    n_pruned: int = 0
    interpret: bool = True
    jax_version: str = ""
    created_at: str = ""

    @property
    def key(self) -> str:
        return make_key(self.kernel, self.shape, self.dtype, self.chip,
                        self.interpret)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TuningRecord":
        d = dict(d)
        d["measurements"] = [Measurement(**m)
                             for m in d.get("measurements", [])]
        return cls(**d)


class SchemaMismatch(RuntimeError):
    pass


class Registry:
    """Load/store TuningRecords in one schema-versioned JSON file.

    Writes are atomic (tmp file + rename) so a crashed tune never tears the
    cache.  ``strict=True`` raises on a schema mismatch instead of treating
    the file as empty.
    """

    def __init__(self, path: Optional[str] = None, *, strict: bool = False):
        self.path = path or default_registry_path()
        self.strict = strict
        self._records: Optional[Dict[str, Dict[str, Any]]] = None
        self._dirty: set = set()        # keys written via put() since load

    # -- persistence --------------------------------------------------------

    def load(self) -> Dict[str, Dict[str, Any]]:
        if self._records is not None:
            return self._records
        self._records = {}
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    data = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                if self.strict:
                    raise
                log.warning("tuning registry %s unreadable (%s); starting "
                            "empty", self.path, e)
                return self._records
            version = data.get("schema_version")
            if version != SCHEMA_VERSION:
                if self.strict:
                    raise SchemaMismatch(
                        f"registry {self.path} has schema_version={version}, "
                        f"expected {SCHEMA_VERSION}")
                log.warning("tuning registry %s has schema_version=%s "
                            "(want %s); ignoring stale cache",
                            self.path, version, SCHEMA_VERSION)
                return self._records
            self._records = data.get("records", {})
        return self._records

    def save(self) -> None:
        records = self.load()
        # merge-on-save: re-read the file so concurrent tuners' records
        # survive.  Only keys THIS process wrote via put() overlay the disk
        # view — merely-read keys must not revert another writer's newer
        # record (atomic rename below prevents torn files, this prevents
        # lost updates in both directions)
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    data = json.load(f)
                if data.get("schema_version") == SCHEMA_VERSION:
                    merged = data.get("records", {})
                    merged.update({k: records[k] for k in self._dirty
                                   if k in records})
                    self._records = records = merged
            except (OSError, json.JSONDecodeError):
                pass
        payload = {"schema_version": SCHEMA_VERSION, "records": records}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # -- record access ------------------------------------------------------

    def get(self, kernel: str, shape: Sequence[int], dtype: str,
            chip: str, interpret: bool = True) -> Optional[TuningRecord]:
        raw = self.load().get(make_key(kernel, shape, dtype, chip,
                                       interpret))
        return TuningRecord.from_dict(raw) if raw is not None else None

    def put(self, record: TuningRecord, *, save: bool = True) -> None:
        self.load()[record.key] = record.to_dict()
        self._dirty.add(record.key)
        if save:
            self.save()

    def keys(self) -> List[str]:
        return sorted(self.load())

    def records(self) -> List[TuningRecord]:
        return [TuningRecord.from_dict(v) for _, v in
                sorted(self.load().items())]

    def records_for(self, kernel: str,
                    chip: Optional[str] = None) -> List[TuningRecord]:
        out = []
        for rec in self.records():
            if rec.kernel != kernel:
                continue
            if chip is not None and rec.chip != chip:
                continue
            out.append(rec)
        return out

    def __len__(self) -> int:
        return len(self.load())
