"""Empirical autotuner: time the surviving candidates, cache the winner.

Measurement uses the repo's one canonical timing protocol —
``repro.bench.timing`` (warmup calls excluding compilation/tracing,
``repeats`` timed calls, one-sided IQR outlier rejection, median) — this
module owns no timing loop of its own.  The hard-coded default config is
always measured even if the analytic model pruned it, so every record
carries a tuned-vs-default speedup with full provenance.
"""
from __future__ import annotations

import datetime
import logging
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from ..bench.timing import TimingStats, time_callable   # noqa: F401  (re-export)
from ..core import hardware
from ..core.async_pipeline import Strategy, parse_strategy
from ..obs.trace import get_tracer
from ..kernels import ops
from .registry import Measurement, Registry, TuningRecord
from .search_space import Candidate, TuningTask, default_task

log = logging.getLogger("repro.tuning")


class Autotuner:
    """Drives TuningTasks through the registry-backed measure/cache cycle."""

    def __init__(self, registry: Optional[Registry] = None, *,
                 warmup: int = 1, repeats: int = 5,
                 keep_ratio: Optional[float] = None):
        self.registry = registry if registry is not None else Registry()
        self.warmup = warmup
        self.repeats = repeats
        self.keep_ratio = keep_ratio

    def tune(self, task: TuningTask, *, force: bool = False,
             verbose: bool = False) -> TuningRecord:
        """Return the cached record for the task, measuring on a miss."""
        cached = self.registry.get(task.kernel, task.shape, task.dtype,
                                   task.chip, task.interpret)
        if cached is not None and not force:
            log.info("tuning cache hit: %s", cached.key)
            return cached

        keep_ratio = self.keep_ratio or task.keep_ratio
        survivors, dropped = task.space.pruned(keep_ratio)
        # baseline against the SEED constants, not the live defaults table —
        # apply_registry_defaults may already have installed a tuned winner
        # there, which would collapse speedup_vs_default to ~1.0
        default_cfg = ops.seed_default_config(task.kernel)
        if not any(_config_eq(c.config, default_cfg) for c in survivors):
            # always measure the hard-coded default for the speedup baseline
            survivors = survivors + [task.space.annotate(default_cfg)]
        log.info("tuning %s shape=%s: %d candidates (%d pruned analytically)",
                 task.kernel, task.shape, len(survivors), len(dropped))

        args = task.make_args()
        measurements: List[Measurement] = []
        # the search becomes a span tree (tune -> one span per candidate),
        # so a Perfetto view replays which configs were tried, in what
        # order, at what cost, and which failed
        with get_tracer().span(
                f"tune:{task.kernel}",
                shape="x".join(map(str, task.shape)), dtype=task.dtype,
                chip=task.chip, interpret=task.interpret,
                n_candidates=len(survivors), n_pruned=len(dropped)):
            for cand in survivors:
                meas = self._measure(task, args, cand)
                measurements.append(meas)
                if verbose:
                    status = f"{meas.us_median:10.1f}us" \
                        if meas.error is None else f"FAILED ({meas.error})"
                    print(f"  {_config_str(cand.config):<56s} "
                          f"pred={meas.predicted_us:9.1f}us meas={status}",
                          flush=True)

        ok = [m for m in measurements if m.error is None]
        if not ok:
            raise RuntimeError(
                f"autotuning {task.kernel} {task.shape}: every candidate "
                f"failed; first error: {measurements[0].error}")
        best = min(ok, key=lambda m: m.us_median)
        default_meas = next(
            (m for m in ok if _config_eq(m.config, _encode(default_cfg))),
            None)
        default_us = default_meas.us_median if default_meas else 0.0
        record = TuningRecord(
            kernel=task.kernel, shape=list(task.shape), dtype=task.dtype,
            chip=task.chip, best=best.config, best_us=best.us_median,
            default_us=default_us,
            speedup_vs_default=(default_us / best.us_median
                                if best.us_median and default_us else 0.0),
            measurements=measurements,
            n_candidates=len(survivors), n_pruned=len(dropped),
            interpret=task.interpret, jax_version=jax.__version__,
            created_at=datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds"))
        self.registry.put(record)
        return record

    def _measure(self, task: TuningTask, args: Tuple,
                 cand: Candidate) -> Measurement:
        cfg = _encode(cand.config)
        with get_tracer().span("candidate", config=_config_str(cand.config),
                               predicted_us=cand.predicted_us) as span:
            try:
                stats = time_callable(lambda: task.call(args, cand.config),
                                      warmup=self.warmup,
                                      repeats=self.repeats)
                if span is not None:
                    span.attrs["us_median"] = stats.median
                return Measurement(config=cfg, us_median=stats.median,
                                   us_mean=stats.mean, us_min=stats.best,
                                   us_std=stats.std,
                                   n_trials=len(stats.times_us),
                                   n_outliers=stats.n_outliers,
                                   predicted_us=cand.predicted_us)
            except Exception as e:      # candidate infeasible in practice
                log.warning("candidate %s failed: %s", cfg, e)
                if span is not None:
                    span.attrs["error"] = f"{type(e).__name__}"
                return Measurement(config=cfg,
                                   predicted_us=cand.predicted_us,
                                   error=f"{type(e).__name__}: {e}")


# ---------------------------------------------------------------------------
# Config (de)serialisation: Strategy enums <-> registry JSON strings
# ---------------------------------------------------------------------------

def _encode(config: Dict[str, Any]) -> Dict[str, Any]:
    return {k: (v.value if isinstance(v, Strategy) else v)
            for k, v in config.items()}


def decode_config(config: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(config)
    if isinstance(out.get("strategy"), str):
        out["strategy"] = parse_strategy(out["strategy"])
    return out


def _config_eq(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    return _encode(a) == _encode(b)


def _config_str(config: Dict[str, Any]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(_encode(config).items()))


# ---------------------------------------------------------------------------
# Lookup API
# ---------------------------------------------------------------------------

_REGISTRY_CACHE: Dict[str, Registry] = {}


def _default_registry() -> Registry:
    """Memoized default Registry so per-call-site ``tuned()`` lookups do not
    re-read the JSON file every invocation.  The in-memory view is stable
    for the process lifetime; external registry edits need a new process
    (or an explicit Registry passed in)."""
    from .registry import default_registry_path
    path = default_registry_path()
    reg = _REGISTRY_CACHE.get(path)
    if reg is None:
        reg = _REGISTRY_CACHE[path] = Registry(path)
    return reg


def tuned(kernel: str, shape: Sequence[int], dtype: str = "float32", *,
          chip: Optional[str] = None, interpret: bool = True,
          registry: Optional[Registry] = None,
          fallback_to_default: bool = True) -> Optional[Dict[str, Any]]:
    """Best known config for (kernel, shape, dtype, chip), decoded and ready
    to splat into the ops wrapper:  ``ops.stream(x, **tuned("stream",
    x.shape))``.  On a registry miss falls back to the kernel's SEED
    constants, never to an ``apply_registry_defaults`` install: an installed
    winner was tuned at some *other* (usually larger) shape, and splatting
    it as explicit kwargs would bypass the wrappers' degrade-to-seed net
    (explicit arguments are treated as user intent and never overridden) —
    crashing shapes the install does not tile.  Call the wrapper with no
    config kwargs to use installed defaults with graceful fallback.
    Returns None on a miss if ``fallback_to_default=False``.
    """
    reg = registry if registry is not None else _default_registry()
    rec = reg.get(kernel, tuple(int(s) for s in shape), dtype,
                  chip or hardware.TARGET.name, interpret)
    if rec is not None:
        return decode_config(rec.best)
    return ops.seed_default_config(kernel) if fallback_to_default else None


def apply_registry_defaults(registry: Optional[Registry] = None, *,
                            chip: Optional[str] = None,
                            dtype: Optional[str] = None,
                            interpret: Optional[bool] = None
                            ) -> Dict[str, Dict[str, Any]]:
    """Install registry winners as the kernels' default configs.

    For each kernel with tuned records on this chip, the record with the
    largest problem size wins (closest to production shapes).  ``dtype``
    and ``interpret`` filter on the records' measurement provenance —
    pass ``interpret=False`` on a real TPU so configs timed under the CPU
    Pallas interpreter are never installed for compiled kernels.  Returns
    the {kernel: config} dict that was applied.  serve/train call this at
    startup so every subsequent kernel call uses tuned constants.
    """
    reg = registry if registry is not None else Registry()
    chip = chip or hardware.TARGET.name
    applied: Dict[str, Dict[str, Any]] = {}
    by_kernel: Dict[str, list] = {}
    for r in reg.records():             # parse the registry once, not 7x
        if r.chip == chip \
                and (dtype is None or r.dtype == dtype) \
                and (interpret is None or r.interpret == interpret):
            by_kernel.setdefault(r.kernel, []).append(r)
    for kernel in ops.KERNEL_DEFAULTS:
        recs = by_kernel.get(kernel, [])
        if not recs:
            continue
        def _size(r):
            n = 1
            for s in r.shape:
                n *= s
            return n
        best = max(recs, key=_size)
        cfg = decode_config(best.best)
        try:
            ops.set_default_config(kernel, **cfg)
        except (KeyError, ValueError) as e:
            # one stale record (e.g. a key a newer kernel dropped) costs
            # only that kernel, not the rest of the install
            log.warning("skipping tuned record %s for %s: %s",
                        _config_str(cfg), kernel, e)
            continue
        applied[kernel] = cfg
        log.info("tuned defaults for %s <- %s (%.1fus, %.2fx vs default)",
                 kernel, _config_str(cfg), best.best_us,
                 best.speedup_vs_default or 1.0)
    return applied


def apply_tuned_kernel_defaults(registry_path: Optional[str] = None
                                ) -> Dict[str, Dict[str, Any]]:
    """Best-effort startup installer for serve/train entry points.

    Loads the persistent registry, filters to measurements matching this
    process's backend (compiled records on TPU, interpreter records
    elsewhere), and installs the winners as kernel defaults.  A missing or
    stale registry is a silent no-op — startup must succeed cold."""
    try:
        interpret = jax.default_backend() != "tpu"
        applied = apply_registry_defaults(Registry(registry_path),
                                          interpret=interpret)
        if applied:
            log.info("autotuned kernel defaults installed for: %s",
                     ", ".join(sorted(applied)))
        return applied
    except Exception as e:              # registry problems never block startup
        log.warning("tuning registry unavailable (%s); using seed defaults",
                    e)
        return {}


def tune_kernel(kernel: str, *, shape: Optional[Sequence[int]] = None,
                dtype: str = "float32", registry: Optional[Registry] = None,
                interpret: bool = True, force: bool = False,
                warmup: int = 1, repeats: int = 5,
                verbose: bool = False) -> TuningRecord:
    """One-call convenience: build the default task and tune it."""
    task = default_task(kernel, shape=shape, dtype=dtype,
                        interpret=interpret)
    tuner = Autotuner(registry, warmup=warmup, repeats=repeats)
    return tuner.tune(task, force=force, verbose=verbose)
