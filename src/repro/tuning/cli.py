"""Autotuner command line.

    PYTHONPATH=src python -m repro.tuning.cli tune --kernel stream
    PYTHONPATH=src python -m repro.tuning.cli tune --all
    PYTHONPATH=src python -m repro.tuning.cli show [--kernel stream]
    PYTHONPATH=src python -m repro.tuning.cli export --out tuned.csv

The registry path defaults to ``./tuning_registry.json`` (override with
``--registry`` or the REPRO_TUNING_REGISTRY environment variable).  A second
``tune`` of the same (kernel, shape, dtype, chip) cell is a cache hit and
does no measurement; pass ``--force`` to re-measure.
"""
from __future__ import annotations

import argparse
import csv
import json
import logging
import sys
import time
from typing import List, Optional

from . import registry as reg_mod
from .autotuner import Autotuner
from .registry import Registry
from .search_space import KERNELS, default_task


def _parse_shape(text: Optional[str]):
    if not text:
        return None
    return tuple(int(p) for p in text.replace("x", ",").split(",") if p)


def _fmt_config(cfg) -> str:
    cfg = dict(cfg)
    strat = cfg.pop("strategy", "?")
    strat = getattr(strat, "value", strat)
    rest = ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))
    return f"{strat}[{rest}]"


def cmd_tune(args) -> int:
    registry = Registry(args.registry)
    tuner = Autotuner(registry, warmup=args.warmup, repeats=args.repeats)
    kernels: List[str] = list(KERNELS) if args.all else [args.kernel]
    if not kernels or kernels == [None]:
        print("error: pass --kernel NAME or --all", file=sys.stderr)
        return 2
    if args.all and args.shape:
        print("error: --shape applies to one kernel; it cannot be combined "
              "with --all (kernels have different shape ranks)",
              file=sys.stderr)
        return 2
    for kernel in kernels:
        task = default_task(kernel, shape=_parse_shape(args.shape),
                            dtype=args.dtype, interpret=not args.compiled)
        t0 = time.time()
        cached = registry.get(task.kernel, task.shape, task.dtype,
                              task.chip, task.interpret)
        try:
            rec = tuner.tune(task, force=args.force, verbose=args.verbose)
        except RuntimeError as e:       # e.g. shape no candidate can tile
            print(f"error: {e}", file=sys.stderr)
            return 1
        hit = cached is not None and not args.force
        what = "cache hit" if hit else f"tuned in {time.time() - t0:.1f}s"
        speed = (f" {rec.speedup_vs_default:.2f}x vs default"
                 if rec.speedup_vs_default else "")
        print(f"{rec.kernel:<16s} shape={'x'.join(map(str, rec.shape))} "
              f"dtype={rec.dtype} chip={rec.chip}: "
              f"best={_fmt_config(rec.best)} {rec.best_us:.1f}us{speed} "
              f"[{what}, {rec.n_candidates} measured, "
              f"{rec.n_pruned} pruned]")
    print(f"registry: {registry.path} ({len(registry)} records)")
    return 0


def cmd_show(args) -> int:
    registry = Registry(args.registry)
    records = registry.records()
    if args.kernel:
        records = [r for r in records if r.kernel == args.kernel]
    if not records:
        print(f"no records in {registry.path}")
        return 1
    print(f"{'kernel':<16s} {'shape':<14s} {'dtype':<9s} {'chip':<8s} "
          f"{'best config':<40s} {'us':>10s} {'vs_default':>10s}")
    for r in records:
        print(f"{r.kernel:<16s} {'x'.join(map(str, r.shape)):<14s} "
              f"{r.dtype:<9s} {r.chip:<8s} {_fmt_config(r.best):<40s} "
              f"{r.best_us:>10.1f} "
              f"{(f'{r.speedup_vs_default:.2f}x' if r.speedup_vs_default else '-'):>10s}")
        if args.verbose:
            for m in sorted(r.measurements,
                            key=lambda m: m.us_median or 1e30):
                status = f"{m.us_median:10.1f}us" if m.error is None \
                    else f"FAILED: {m.error}"
                print(f"    {_fmt_config(m.config):<44s} "
                      f"pred={m.predicted_us:9.1f}us  {status}")
    return 0


def cmd_export(args) -> int:
    registry = Registry(args.registry)
    records = registry.records()
    rows = []
    for r in records:
        for m in r.measurements:
            rows.append({
                "kernel": r.kernel, "shape": "x".join(map(str, r.shape)),
                "dtype": r.dtype, "chip": r.chip,
                "config": _fmt_config(m.config),
                "us_median": m.us_median, "us_mean": m.us_mean,
                "us_min": m.us_min, "us_std": m.us_std,
                "n_trials": m.n_trials, "predicted_us": m.predicted_us,
                "is_best": m.config == r.best, "error": m.error or "",
            })
    if args.format == "csv":
        w = csv.DictWriter(args.out, fieldnames=list(rows[0]) if rows else
                           ["kernel"])
        w.writeheader()
        w.writerows(rows)
    else:
        json.dump({"schema_version": reg_mod.SCHEMA_VERSION,
                   "measurements": rows}, args.out, indent=1)
        args.out.write("\n")
    print(f"exported {len(rows)} measurements from {len(records)} records",
          file=sys.stderr)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.tuning.cli",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--registry", default=None,
                    help="registry JSON path (default ./tuning_registry.json"
                         " or $REPRO_TUNING_REGISTRY)")
    ap.add_argument("-v", "--verbose", action="store_true")
    sub = ap.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("tune", help="search + measure + cache best configs")
    t.add_argument("--kernel", choices=KERNELS, default=None)
    t.add_argument("--all", action="store_true",
                   help="tune every kernel at its default shape")
    t.add_argument("--shape", default=None,
                   help="problem shape, e.g. 512x256 (kernel default "
                        "otherwise)")
    t.add_argument("--dtype", default="float32")
    t.add_argument("--repeats", type=int, default=5)
    t.add_argument("--warmup", type=int, default=1)
    t.add_argument("--force", action="store_true",
                   help="re-measure even on a cache hit")
    t.add_argument("--compiled", action="store_true",
                   help="compile for the real backend instead of the CPU "
                        "Pallas interpreter (use on TPU)")
    t.set_defaults(fn=cmd_tune)

    s = sub.add_parser("show", help="print cached records")
    s.add_argument("--kernel", choices=KERNELS, default=None)
    s.set_defaults(fn=cmd_show)

    e = sub.add_parser("export", help="dump full measurement provenance")
    e.add_argument("--out", type=argparse.FileType("w"), default=sys.stdout)
    e.add_argument("--format", choices=("json", "csv"), default="json")
    e.set_defaults(fn=cmd_export)

    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO if args.verbose
                        else logging.WARNING)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
