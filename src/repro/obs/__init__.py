"""Observability: span tracing, serving metrics, and the regression gate.

The third leg next to measurement (``repro.bench``) and search
(``repro.tuning``) — everything between "scenario start" and "median µs"
becomes inspectable events:

  trace      nested context-manager spans on the monotonic clock, a
             thread-safe buffer, JSONL sink, and Chrome-trace/Perfetto
             export; OFF by default (one attribute check on the hot path)
  metrics    labeled counters / gauges / histograms with quantile
             snapshots (the serving loop's TTFT & per-token latencies)
  compare    noise-aware BENCH_*.json regression gate — median +/- k*IQR
             per cell, optional host-speed normalization
  cli        python -m repro.obs.cli {summary,export-trace,compare,profile}

Import note: only ``trace``/``metrics`` (stdlib-only) are imported
eagerly — ``bench.timing`` imports ``obs.trace`` while ``repro.bench``
itself may be mid-import, so this package must not import ``compare``
(which needs ``bench.results``) at import time.  Import ``repro.obs
.compare`` / ``repro.obs.cli`` directly.
"""
from . import trace                                         # noqa: F401
from .trace import Span, Tracer, chrome_trace, tracer
from . import metrics                                       # noqa: F401
from .metrics import (Counter, Gauge, Histogram, Registry, counter, gauge,
                      histogram, registry)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "Span", "Tracer",
    "chrome_trace", "counter", "gauge", "histogram", "metrics", "registry",
    "trace", "tracer",
]
