"""Observability command line.

    PYTHONPATH=src python -m repro.obs.cli summary --trace t.jsonl
    PYTHONPATH=src python -m repro.obs.cli summary --metrics m.json
    PYTHONPATH=src python -m repro.obs.cli export-trace t.jsonl t.chrome.json
    PYTHONPATH=src python -m repro.obs.cli compare BASE.json NEW.json
    PYTHONPATH=src python -m repro.obs.cli profile <arch> <shape>

``summary`` aggregates a span JSONL (per-name count/total/p50) and/or
pretty-prints a metrics snapshot.  ``export-trace`` converts a span JSONL
to Chrome trace-event JSON loadable at https://ui.perfetto.dev.
``compare`` is the noise-aware regression gate over two schema-v2
BENCH_*.json reports — exit code 1 when any cell regresses beyond its
measured noise band, so CI can gate on it directly.  ``profile`` compiles
one dry-run cell and prints its top HLO ops by weighted cost (the old
``experiments/profile_cell.py`` report).

jax is only imported by ``profile`` — the other subcommands are pure
stdlib and safe in any environment.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List


def _fail(msg: str) -> "SystemExit":
    return SystemExit(f"error: {msg}")


# ---------------------------------------------------------------------------
# summary
# ---------------------------------------------------------------------------

def _span_summary(path: str, stream) -> None:
    from .metrics import quantile
    from .trace import load_jsonl
    spans = load_jsonl(path)
    if not spans:
        print(f"(no spans in {path})", file=stream)
        return
    by_name: dict = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s.dur_us)
    t0 = min(s.t0_us for s in spans)
    t1 = max(s.t1_us for s in spans)
    print(f"{len(spans)} spans over {(t1 - t0) / 1e3:.1f}ms "
          f"(trace {spans[0].trace_id})", file=stream)
    print(f"{'name':<28s} {'count':>6s} {'total_ms':>10s} "
          f"{'p50_us':>12s} {'max_us':>12s}", file=stream)
    rows = sorted(by_name.items(), key=lambda kv: -sum(kv[1]))
    for name, durs in rows:
        durs = sorted(durs)
        print(f"{name:<28s} {len(durs):>6d} {sum(durs) / 1e3:>10.2f} "
              f"{quantile(durs, 0.5):>12.1f} {durs[-1]:>12.1f}",
              file=stream)


def _metrics_summary(path: str, stream) -> None:
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    if not rows:
        print(f"(no metric rows in {path})", file=stream)
        return
    print(f"{'metric':<26s} {'kind':<10s} {'labels':<24s} value", file=stream)
    for r in rows:
        labels = ",".join(f"{k}={v}"
                          for k, v in sorted(r.get("labels", {}).items()))
        if r["kind"] == "histogram":
            val = (f"n={r['count']} mean={r['mean']:.2f} "
                   f"p50={r['p50']:.2f} p99={r['p99']:.2f}")
        else:
            val = f"{r['value']}"
        print(f"{r['name']:<26s} {r['kind']:<10s} {labels:<24s} {val}",
              file=stream)


def cmd_summary(args) -> int:
    if not args.trace and not args.metrics:
        raise _fail("summary needs --trace and/or --metrics")
    if args.trace:
        _span_summary(args.trace, sys.stdout)
    if args.metrics:
        if args.trace:
            print()
        _metrics_summary(args.metrics, sys.stdout)
    return 0


# ---------------------------------------------------------------------------
# export-trace
# ---------------------------------------------------------------------------

def cmd_export_trace(args) -> int:
    from .trace import chrome_trace, load_jsonl
    spans = load_jsonl(args.jsonl)
    doc = chrome_trace(spans)
    with open(args.out, "w") as f:
        json.dump(doc, f)
    print(f"wrote {len(doc['traceEvents'])} events to {args.out} "
          f"(load in https://ui.perfetto.dev)")
    return 0


# ---------------------------------------------------------------------------
# compare
# ---------------------------------------------------------------------------

def cmd_compare(args) -> int:
    from .compare import compare_reports, format_compare
    from ..bench.results import BenchReport
    base = BenchReport.load(args.base)
    new = BenchReport.load(args.new)
    res = compare_reports(base, new, k=args.k, rel_floor=args.rel_floor,
                          normalize=args.normalize)
    print(format_compare(res, base_path=args.base, new_path=args.new,
                         verbose=args.verbose))
    if args.json:
        res.save(args.json)
        print(f"# wrote verdicts to {args.json}")
    return 1 if res.n_regressions else 0


# ---------------------------------------------------------------------------
# profile
# ---------------------------------------------------------------------------

def cmd_profile(args) -> int:
    from ..launch.profile import (ensure_host_devices, format_report,
                                  profile_report)
    ensure_host_devices()
    report = profile_report(args.arch, args.shape, k=args.top)
    print(format_report(args.arch, args.shape, report))
    return 0


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

def main(argv: List[str] = None) -> int:
    from .compare import DEFAULT_K, DEFAULT_REL_FLOOR
    ap = argparse.ArgumentParser(prog="repro.obs.cli",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summary",
                       help="aggregate a span JSONL / metrics snapshot")
    p.add_argument("--trace", default=None, metavar="JSONL",
                   help="span JSONL written by --trace / save_jsonl")
    p.add_argument("--metrics", default=None, metavar="JSON",
                   help="metrics snapshot written by Registry.save")
    p.set_defaults(fn=cmd_summary)

    p = sub.add_parser("export-trace",
                       help="span JSONL -> Chrome/Perfetto trace JSON")
    p.add_argument("jsonl")
    p.add_argument("out")
    p.set_defaults(fn=cmd_export_trace)

    p = sub.add_parser("compare",
                       help="noise-aware regression gate over two "
                            "BENCH_*.json (exit 1 on regression)")
    p.add_argument("base", help="baseline schema-v2 report")
    p.add_argument("new", help="candidate schema-v2 report")
    p.add_argument("-k", type=float, default=DEFAULT_K,
                   help="noise-band width in IQRs (default %(default)s)")
    p.add_argument("--rel-floor", type=float, default=DEFAULT_REL_FLOOR,
                   help="minimum band as a fraction of the baseline median "
                        "(default %(default)s)")
    p.add_argument("--normalize", action="store_true",
                   help="divide out the global median new/base ratio first "
                        "(absorbs a uniformly faster/slower host)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the verdicts as JSON to PATH")
    p.add_argument("--verbose", action="store_true",
                   help="print every cell, not just non-pass verdicts")
    p.set_defaults(fn=cmd_compare)

    p = sub.add_parser("profile",
                       help="compile one dry-run cell, print top HLO ops "
                            "by weighted cost")
    p.add_argument("arch")
    p.add_argument("shape")
    p.add_argument("--top", type=int, default=10,
                   help="ops per table (default %(default)s)")
    p.set_defaults(fn=cmd_profile)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
