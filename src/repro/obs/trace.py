"""Span-based tracing: what happened between "scenario start" and "median".

The paper's method only works because every phase was individually timed;
this module makes the repo's own measurement stack observable the same
way.  A ``Span`` is one named interval on the process's monotonic clock
(``time.perf_counter``) with attributes, a parent, and a trace id; the
``Tracer`` keeps a thread-safe in-process buffer of finished spans and
exports it two ways:

  JSONL          one span per line — greppable, appendable, diffable
  Chrome trace   the ``traceEvents`` JSON that chrome://tracing and
                 https://ui.perfetto.dev load directly (complete "X"
                 events; span nesting becomes track stacking)

Tracing is OFF by default and the disabled path is a single attribute
check — the canonical timer's hot loop must not move by even a
microsecond when nobody is tracing.  Producers therefore either use
``tracer.span(...)`` as a context manager (fine outside timed regions) or
``tracer.record(name, t0, t1, ...)`` to log an interval *retroactively*
from timestamps they already took (``bench.timing`` does this: the timed
region contains zero tracing code).

Nesting is tracked per thread: a span opened while another is open on the
same thread becomes its child, and ``record()`` attaches to the innermost
open span.  Span attributes stay mutable until export, so producers may
annotate after the fact (e.g. flagging which trials were outlier-rejected
once the rejection ran).
"""
from __future__ import annotations

import json
import os
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, IO, List, Optional, Union

__all__ = ["Span", "Tracer", "tracer", "get_tracer", "enable", "disable",
           "load_jsonl", "chrome_trace"]


def _new_id() -> str:
    return uuid.uuid4().hex[:16]


@dataclass
class Span:
    """One finished (or still-open) named interval."""
    name: str
    t0_us: float                        # perf_counter-based, microseconds
    t1_us: Optional[float] = None       # None while still open
    attrs: Dict[str, Any] = field(default_factory=dict)
    span_id: str = field(default_factory=_new_id)
    parent_id: Optional[str] = None
    trace_id: str = ""
    thread_id: int = 0

    @property
    def dur_us(self) -> float:
        return (self.t1_us - self.t0_us) if self.t1_us is not None else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "t0_us": self.t0_us, "t1_us": self.t1_us,
                "dur_us": self.dur_us, "attrs": self.attrs,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "trace_id": self.trace_id, "thread_id": self.thread_id}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Span":
        return cls(name=d["name"], t0_us=d["t0_us"], t1_us=d.get("t1_us"),
                   attrs=dict(d.get("attrs", {})),
                   span_id=d.get("span_id", ""),
                   parent_id=d.get("parent_id"),
                   trace_id=d.get("trace_id", ""),
                   thread_id=int(d.get("thread_id", 0)))


def _now_us() -> float:
    return time.perf_counter() * 1e6


class _NoopSpanCtx:
    """The disabled path: one shared immutable context manager."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpanCtx()


class _SpanCtx:
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(self._span)
        return False


class Tracer:
    """Thread-safe in-process span buffer.  One module-level instance
    (``tracer()``) serves the whole repo; tests may make their own."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.trace_id = _new_id()
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._local = threading.local()

    # -- lifecycle ----------------------------------------------------------

    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
        self.trace_id = _new_id()

    # -- span production ----------------------------------------------------

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def current(self) -> Optional[Span]:
        st = self._stack()
        return st[-1] if st else None

    def span(self, name: str, **attrs: Any):
        """Context manager for a live span; yields the ``Span`` (or None
        when tracing is disabled, so ``with ... as sp: if sp:`` guards)."""
        if not self.enabled:
            return _NOOP
        parent = self.current()
        sp = Span(name=name, t0_us=_now_us(), attrs=attrs,
                  parent_id=parent.span_id if parent else None,
                  trace_id=self.trace_id,
                  thread_id=threading.get_ident() & 0x7FFFFFFF)
        return _SpanCtx(self, sp)

    def _push(self, sp: Span) -> None:
        self._stack().append(sp)

    def _pop(self, sp: Span) -> None:
        sp.t1_us = _now_us()
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        with self._lock:
            self._spans.append(sp)

    def record(self, name: str, t0_s: float, t1_s: float,
               **attrs: Any) -> Optional[Span]:
        """Log an interval retroactively from ``time.perf_counter()``
        readings the caller already took — zero tracing code runs inside
        the interval itself.  Attaches under the innermost open span."""
        if not self.enabled:
            return None
        parent = self.current()
        sp = Span(name=name, t0_us=t0_s * 1e6, t1_us=t1_s * 1e6, attrs=attrs,
                  parent_id=parent.span_id if parent else None,
                  trace_id=self.trace_id,
                  thread_id=threading.get_ident() & 0x7FFFFFFF)
        with self._lock:
            self._spans.append(sp)
        return sp

    # -- consumption --------------------------------------------------------

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def save_jsonl(self, out: Union[str, IO[str]]) -> int:
        """Write one span per line; returns the number written."""
        spans = self.spans()
        if hasattr(out, "write"):
            for sp in spans:
                out.write(json.dumps(sp.to_dict(), sort_keys=True) + "\n")
        else:
            with open(out, "w") as f:
                for sp in spans:
                    f.write(json.dumps(sp.to_dict(), sort_keys=True) + "\n")
        return len(spans)

    def chrome_trace(self) -> Dict[str, Any]:
        return chrome_trace(self.spans())


def load_jsonl(path: str) -> List[Span]:
    spans = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                spans.append(Span.from_dict(json.loads(line)))
    return spans


def chrome_trace(spans: List[Span]) -> Dict[str, Any]:
    """The Chrome trace-event JSON (Perfetto-loadable): complete "X" events,
    ``ts``/``dur`` in microseconds, one track per thread.  Span attributes
    travel in ``args`` (plus the span/parent ids, so the tree survives)."""
    pid = os.getpid()
    events = []
    for sp in spans:
        if sp.t1_us is None:
            continue
        args = {str(k): v for k, v in sp.attrs.items()}
        args["span_id"] = sp.span_id
        if sp.parent_id:
            args["parent_id"] = sp.parent_id
        events.append({
            "name": sp.name, "ph": "X", "cat": "repro",
            "ts": sp.t0_us, "dur": sp.dur_us,
            "pid": pid, "tid": sp.thread_id or pid, "args": args,
        })
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs.trace"}}


# ---------------------------------------------------------------------------
# The process-wide tracer
# ---------------------------------------------------------------------------

_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER


#: alias kept for hot-path importers (``from repro.obs.trace import
#: get_tracer``) — same object, clearer intent at the call site.
get_tracer = tracer


def enable() -> Tracer:
    return _TRACER.enable()


def disable() -> Tracer:
    return _TRACER.disable()
