"""Labeled in-process metrics: counters, gauges, histograms.

The serving loop (and anything else with request-shaped work) records into
a ``Registry``; a snapshot is a plain list of dict rows — JSON-dumpable,
renderable by ``experiments/make_report.py``, and printable by
``python -m repro.obs.cli summary``.

  Counter     monotonically increasing total      (requests, tokens)
  Gauge       last-set value                      (queue depth, occupancy)
  Histogram   observations + quantile snapshots   (TTFT, per-token latency)

Metrics are identified by (name, sorted labels): asking the registry for
the same name+labels twice returns the same instance, so call sites never
coordinate.  All three types are thread-safe.  Histograms keep samples in
a fixed-size ring (default 8192) — once full, new observations overwrite
the oldest, so quantiles describe the recent window; ``count``/``sum``
stay exact totals.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, IO, List, Optional, Tuple, Union

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "registry",
           "counter", "gauge", "histogram", "quantile"]

#: quantiles every histogram snapshot reports.
SNAPSHOT_QUANTILES = (0.5, 0.9, 0.99)


def quantile(sorted_samples: List[float], q: float) -> float:
    """Linear-interpolation quantile over an already-sorted list."""
    if not sorted_samples:
        return 0.0
    if len(sorted_samples) == 1:
        return sorted_samples[0]
    pos = q * (len(sorted_samples) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_samples) - 1)
    frac = pos - lo
    return sorted_samples[lo] * (1.0 - frac) + sorted_samples[hi] * frac


class _Metric:
    kind = "?"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()

    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)

    def _row(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name,
                "labels": self.label_dict()}


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return dict(self._row(), value=self._value)


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return dict(self._row(), value=self._value)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, labels, max_samples: int = 8192):
        super().__init__(name, labels)
        self.max_samples = max(int(max_samples), 1)
        self._samples: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            if len(self._samples) < self.max_samples:
                self._samples.append(v)
            else:                       # ring overwrite: recent window
                self._samples[self._count % self.max_samples] = v
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._count

    def samples(self) -> List[float]:
        """Copy of the retained sample window, in observation order (the
        bench serving rows export these as raw ``times_us``)."""
        with self._lock:
            return list(self._samples)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            samples = sorted(self._samples)
            count, total = self._count, self._sum
            lo = self._min if count else 0.0
            hi = self._max if count else 0.0
        row = dict(self._row(), count=count, sum=total, min=lo, max=hi,
                   mean=(total / count if count else 0.0))
        for q in SNAPSHOT_QUANTILES:
            row[f"p{int(q * 100)}"] = quantile(samples, q)
        return row


class Registry:
    """Get-or-create store of labeled metrics."""

    _TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, Tuple[Tuple[str, str], ...]],
                            _Metric] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, Any]) -> _Metric:
        key = (kind, name,
               tuple(sorted((str(k), str(v)) for k, v in labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = self._TYPES[kind](name, key[2])
            return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get("histogram", name, labels)

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            metrics = list(self._metrics.values())
        return [m.snapshot() for m in sorted(
            metrics, key=lambda m: (m.name, m.labels))]

    def to_dict(self) -> Dict[str, Any]:
        return {"schema_version": 1, "kind": "obs-metrics",
                "rows": self.snapshot()}

    def save(self, out: Union[str, IO[str]]) -> None:
        if hasattr(out, "write"):
            json.dump(self.to_dict(), out, indent=1, sort_keys=True)
            out.write("\n")
        else:
            with open(out, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
                f.write("\n")


# ---------------------------------------------------------------------------
# The process-wide registry
# ---------------------------------------------------------------------------

_REGISTRY = Registry()


def registry() -> Registry:
    return _REGISTRY


def counter(name: str, **labels: Any) -> Counter:
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: Any) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels: Any) -> Histogram:
    return _REGISTRY.histogram(name, **labels)
