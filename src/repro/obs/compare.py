"""Noise-aware regression gate over two ``BENCH_*.json`` reports.

A naive percent threshold either cries wolf on noisy cells or sleeps
through regressions on quiet ones.  This gate uses each cell's *measured
spread*: a cell regresses only when the new median sits outside the old
median by more than ``k`` times the BASELINE run's inter-quartile range
(the same robust statistic the timing protocol's outlier rejection uses),
with a small relative floor so a zero-IQR cell cannot flag on scheduler
jitter.  The band deliberately ignores the candidate run's own spread —
a regression that also inflates its variance must not widen its own gate.

Cells are keyed by (scenario, chip); only ``kind == "measured"`` rows are
gated — ``kind == "model"`` rows are deterministic roofline predictions,
so a change there is a code change, not a measurement regression.

``normalize=True`` additionally divides the new medians by the run-pair's
global median ratio before gating, so a uniformly slower/faster *host*
(CI machine lottery) does not drown the one kernel that actually
regressed: only cells that move relative to the rest of their own sweep
can fail.

Serving cells carry two extra gated metrics beyond ``us_median``, emitted
as synthetic ``scenario:metric`` rows: ``tokens_per_s`` (higher is
better, so the verdict is inverted; under ``normalize`` the new value is
*multiplied* by the host scale, since a uniformly slower host depresses
throughput by exactly the factor it inflates latencies) and
``cache_hit_ratio`` (a deterministic scheduling property in [0, 1], gated
with a small absolute band and never host-normalized).

The verdict rows serialize to an ``obs-compare`` JSON document that
``experiments/make_report.py`` renders and CI archives next to the bench
trajectory.
"""
from __future__ import annotations

import json
import statistics
from dataclasses import dataclass, field
from typing import Any, Dict, IO, List, Optional, Tuple, Union

from ..bench.results import BenchReport, BenchResult
from .metrics import quantile

__all__ = ["CellVerdict", "CompareResult", "compare_reports",
           "format_compare", "cell_noise_us", "DEFAULT_K",
           "DEFAULT_REL_FLOOR"]

#: how many IQRs outside the baseline median a cell must move to flag.
DEFAULT_K = 3.0

#: relative noise floor: |delta| below this fraction of the baseline median
#: never flags, even for a cell whose measured spread was ~0.
DEFAULT_REL_FLOOR = 0.05

#: IQR ~= 1.349 sigma for a normal distribution — the fallback when a row
#: carries only ``us_std`` (reports written before raw trials were kept).
_STD_TO_IQR = 1.349

#: hit ratio is deterministic given the trace, but admission order can
#: shift a block boundary; allow this much absolute movement before
#: flagging.
HIT_RATIO_BAND = 0.02

#: extra per-cell metrics gated as synthetic ``scenario:metric`` rows:
#: (key, higher_is_better, absolute band or None for rel_floor * base,
#:  host_scaled).  Cells lacking the key (all kernel rows) are skipped.
_EXTRA_METRICS = (
    ("tokens_per_s", True, None, True),
    ("cache_hit_ratio", True, HIT_RATIO_BAND, False),
)


def _iqr(samples: List[float]) -> float:
    s = sorted(samples)
    return quantile(s, 0.75) - quantile(s, 0.25)


def cell_noise_us(metrics: Dict[str, Any]) -> float:
    """One cell's measured spread in microseconds: the IQR of its kept
    trial times when the row carries them, else derived from the std."""
    times = metrics.get("times_us")
    if isinstance(times, (list, tuple)) and len(times) >= 4:
        return _iqr([float(t) for t in times])
    return _STD_TO_IQR * float(metrics.get("us_std", 0.0) or 0.0)


@dataclass
class CellVerdict:
    """Gate outcome for one (scenario, chip) cell."""
    scenario: str
    chip: str
    kernel: str = ""
    strategy: str = ""
    verdict: str = "pass"       # pass | regress | improve | new | missing
    base_us: Optional[float] = None
    new_us: Optional[float] = None
    adj_new_us: Optional[float] = None   # after host normalization
    band_us: float = 0.0        # +/- noise band around the baseline median
    delta_pct: float = 0.0      # (adj_new - base) / base * 100

    def to_dict(self) -> Dict[str, Any]:
        return {"scenario": self.scenario, "chip": self.chip,
                "kernel": self.kernel, "strategy": self.strategy,
                "verdict": self.verdict, "base_us": self.base_us,
                "new_us": self.new_us, "adj_new_us": self.adj_new_us,
                "band_us": self.band_us, "delta_pct": self.delta_pct}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CellVerdict":
        return cls(**d)


@dataclass
class CompareResult:
    """All verdicts plus the gate summary; serializes to obs-compare JSON."""
    verdicts: List[CellVerdict] = field(default_factory=list)
    k: float = DEFAULT_K
    rel_floor: float = DEFAULT_REL_FLOOR
    host_scale: float = 1.0     # global new/base median ratio (1.0 = off)
    normalized: bool = False

    def counts(self) -> Dict[str, int]:
        out = {"pass": 0, "regress": 0, "improve": 0, "new": 0, "missing": 0}
        for v in self.verdicts:
            out[v.verdict] = out.get(v.verdict, 0) + 1
        return out

    @property
    def n_regressions(self) -> int:
        return self.counts()["regress"]

    def to_dict(self) -> Dict[str, Any]:
        return {"schema_version": 1, "kind": "obs-compare",
                "k": self.k, "rel_floor": self.rel_floor,
                "host_scale": self.host_scale,
                "normalized": self.normalized,
                "counts": self.counts(),
                "rows": [v.to_dict() for v in self.verdicts]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CompareResult":
        if d.get("kind") != "obs-compare":
            raise ValueError("not an obs-compare document")
        return cls(verdicts=[CellVerdict.from_dict(r)
                             for r in d.get("rows", [])],
                   k=d.get("k", DEFAULT_K),
                   rel_floor=d.get("rel_floor", DEFAULT_REL_FLOOR),
                   host_scale=d.get("host_scale", 1.0),
                   normalized=d.get("normalized", False))

    def save(self, out: Union[str, IO[str]]) -> None:
        if hasattr(out, "write"):
            json.dump(self.to_dict(), out, indent=1, sort_keys=True)
            out.write("\n")
        else:
            with open(out, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
                f.write("\n")

    @classmethod
    def load(cls, path: str) -> "CompareResult":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def _measured_cells(report: BenchReport) -> Dict[Tuple[str, str], BenchResult]:
    cells = {}
    for r in report.results:
        if r.kind == "measured" and "us_median" in r.metrics:
            cells[(r.scenario, r.chip)] = r
    return cells


def compare_reports(base: BenchReport, new: BenchReport, *,
                    k: float = DEFAULT_K,
                    rel_floor: float = DEFAULT_REL_FLOOR,
                    normalize: bool = False) -> CompareResult:
    """Gate ``new`` against ``base``; see the module docstring for the
    noise model.  Returns every cell's verdict (sorted, regressions
    first) plus the applied parameters."""
    base_cells = _measured_cells(base)
    new_cells = _measured_cells(new)
    common = sorted(set(base_cells) & set(new_cells))

    scale = 1.0
    if normalize and common:
        ratios = [new_cells[c].metrics["us_median"]
                  / base_cells[c].metrics["us_median"]
                  for c in common
                  if base_cells[c].metrics["us_median"] > 0]
        if ratios:
            scale = statistics.median(ratios)
            scale = scale if scale > 0 else 1.0

    verdicts: List[CellVerdict] = []
    for cell in common:
        b, n = base_cells[cell], new_cells[cell]
        base_us = float(b.metrics["us_median"])
        new_us = float(n.metrics["us_median"])
        adj_new = new_us / scale
        band = max(k * cell_noise_us(b.metrics), rel_floor * base_us)
        if adj_new > base_us + band:
            verdict = "regress"
        elif adj_new < base_us - band:
            verdict = "improve"
        else:
            verdict = "pass"
        verdicts.append(CellVerdict(
            scenario=b.scenario, chip=b.chip, kernel=b.kernel,
            strategy=n.strategy, verdict=verdict, base_us=base_us,
            new_us=new_us, adj_new_us=adj_new, band_us=band,
            delta_pct=((adj_new - base_us) / base_us * 100.0
                       if base_us else 0.0)))
        for key, higher_better, abs_band, scaled in _EXTRA_METRICS:
            if key not in b.metrics or key not in n.metrics:
                continue
            base_v = float(b.metrics[key])
            new_v = float(n.metrics[key])
            # a slower host divides throughput where it multiplies time,
            # so the correction runs the other way for these rows
            adj_v = new_v * scale if scaled else new_v
            vband = (abs_band if abs_band is not None
                     else rel_floor * abs(base_v))
            lo, hi = base_v - vband, base_v + vband
            if adj_v < lo:
                mverdict = "regress" if higher_better else "improve"
            elif adj_v > hi:
                mverdict = "improve" if higher_better else "regress"
            else:
                mverdict = "pass"
            verdicts.append(CellVerdict(
                scenario=f"{b.scenario}:{key}", chip=b.chip,
                kernel=b.kernel, strategy=n.strategy, verdict=mverdict,
                base_us=base_v, new_us=new_v, adj_new_us=adj_v,
                band_us=vband,
                delta_pct=((adj_v - base_v) / base_v * 100.0
                           if base_v else 0.0)))

    for cell in sorted(set(base_cells) - set(new_cells)):
        b = base_cells[cell]
        verdicts.append(CellVerdict(
            scenario=b.scenario, chip=b.chip, kernel=b.kernel,
            strategy=b.strategy, verdict="missing",
            base_us=float(b.metrics["us_median"])))
    for cell in sorted(set(new_cells) - set(base_cells)):
        n = new_cells[cell]
        verdicts.append(CellVerdict(
            scenario=n.scenario, chip=n.chip, kernel=n.kernel,
            strategy=n.strategy, verdict="new",
            new_us=float(n.metrics["us_median"])))

    order = {"regress": 0, "missing": 1, "improve": 2, "new": 3, "pass": 4}
    verdicts.sort(key=lambda v: (order[v.verdict], v.scenario, v.chip))
    return CompareResult(verdicts=verdicts, k=k, rel_floor=rel_floor,
                         host_scale=scale, normalized=normalize)


def format_compare(res: CompareResult, *, base_path: str = "base",
                   new_path: str = "new", verbose: bool = False) -> str:
    """Human-readable gate report.  Non-pass verdicts always print;
    ``verbose`` adds the passing cells too."""
    c = res.counts()
    lines = [f"compare: {new_path} vs {base_path} "
             f"(k={res.k:g}, rel_floor={res.rel_floor:g}"
             + (f", host_scale={res.host_scale:.3f}" if res.normalized
                else "") + ")",
             "  " + "  ".join(f"{k}={v}" for k, v in c.items())]
    shown = [v for v in res.verdicts
             if verbose or v.verdict != "pass"]
    if shown:
        lines.append(f"  {'verdict':<8s} {'scenario':<36s} {'chip':<10s} "
                     f"{'base_us':>10s} {'new_us':>10s} {'band_us':>9s} "
                     f"{'delta':>8s}")
    for v in shown:
        base_s = f"{v.base_us:.1f}" if v.base_us is not None else "-"
        new_s = f"{v.adj_new_us:.1f}" if v.adj_new_us is not None else \
            (f"{v.new_us:.1f}" if v.new_us is not None else "-")
        delta = f"{v.delta_pct:+.1f}%" \
            if v.verdict in ("pass", "regress", "improve") else "-"
        lines.append(f"  {v.verdict:<8s} {v.scenario:<36s} {v.chip:<10s} "
                     f"{base_s:>10s} {new_s:>10s} {v.band_us:>9.2f} "
                     f"{delta:>8s}")
    lines.append("GATE: " + ("REGRESSED" if res.n_regressions else "ok")
                 + f" ({res.n_regressions} regression(s))")
    return "\n".join(lines)
