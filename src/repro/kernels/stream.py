"""Paper §4.1 microbenchmark kernel (Pallas TPU).

Element-wise application of f(x) = 0.5*x + 0.5 for a configurable number of
iterations (= configurable arithmetic intensity), streaming tiles
HBM -> VMEM under one of the four asynchronous-copy strategies and streaming
results VMEM -> HBM through an N-deep write-back ring.

Grid: one program per row-block; each program streams ``n_tiles`` tiles of
``tile_rows`` x ``width`` elements from its slice of the input.  The
pipeline shape (ring depth, wait-group, out-ring depth) comes from a
``PipelineSpec``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   WriteBack, as_spec, compiler_params, emit,
                                   scratch_for, writeback_scratch)


def _apply_f(val, iters: int):
    if iters <= 0:
        return val
    return jax.lax.fori_loop(
        0, iters, lambda _, v: v * 0.5 + 0.5, val, unroll=min(iters, 8))


def _stream_kernel(x_hbm, o_hbm, in_buf, out_buf, stage_buf, in_sems, out_sems,
                   *, spec: PipelineSpec, n_tiles: int, tile_rows: int,
                   iters: int):
    pid = pl.program_id(0)
    base = pid * n_tiles * tile_rows

    stream = TileStream(
        hbm=x_hbm, vmem=in_buf, sem=in_sems,
        index=lambda i: (pl.ds(base + i * tile_rows, tile_rows), slice(None)),
        depth=spec.ring_depth)

    wb = WriteBack(
        hbm=o_hbm, vmem=out_buf, sem=out_sems,
        index=lambda i: (pl.ds(base + i * tile_rows, tile_rows), slice(None)),
        depth=spec.out_depth)

    if spec.strategy == Strategy.DROP_OFF:
        def compute_value(i, vals):
            wb.push(i, _apply_f(vals[0], iters))
        emit(spec, [stream], n_tiles, compute_value)
    else:
        def compute(i, bufs):
            wb.push(i, _apply_f(bufs[0][...], iters))
        emit(spec, [stream], n_tiles, compute, staging=[stage_buf])

    wb.drain(n_tiles)


def stream_pallas(x: jax.Array, *, iters: int = 1,
                  spec: PipelineSpec = PipelineSpec(),
                  tile_rows: int = 8, n_tiles: int = 4,
                  interpret: bool = False) -> jax.Array:
    """Run the microbenchmark kernel.  x: (rows, width); rows must equal
    g * n_tiles * tile_rows for an integer grid g."""
    spec = as_spec(spec)
    rows, width = x.shape
    block = n_tiles * tile_rows
    if rows % block:
        raise ValueError(f"rows={rows} not divisible by n_tiles*tile_rows={block}")
    grid = rows // block
    in_buf, in_sems, stage = scratch_for(spec, (tile_rows, width), x.dtype)
    out_buf, out_sems = writeback_scratch(spec, (tile_rows, width), x.dtype)
    kernel = functools.partial(
        _stream_kernel, spec=spec, n_tiles=n_tiles,
        tile_rows=tile_rows, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[in_buf, out_buf, stage, in_sems, out_sems],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",)),
    )(x)


def stream_flops_bytes(x_shape: Tuple[int, int], iters: int,
                       dtype_bytes: int = 4) -> Tuple[float, float]:
    """Analytic flops/bytes for the roofline positioning (paper Fig. 3a):
    2 flops per element per iteration; one read + one write per element."""
    n = float(x_shape[0] * x_shape[1])
    return 2.0 * n * iters, 2.0 * n * dtype_bytes
