"""Blocked LU decomposition (Rodinia LUD) as Pallas TPU kernels.

Keeps Rodinia's three-kernel structure per diagonal step k:
  lud_diagonal   factor the (bs,bs) pivot block (Doolittle, no pivoting)
  lud_perimeter  triangular solves for the block row (L^-1 A) and block
                 column (A U^-1)
  lud_internal   trailing update C -= L @ U  — the matmul hot spot where the
                 paper's async streaming pays (A100: 1.25-1.32x, pattern
                 flips from Register-Bypass to Overlap with input size)

The internal kernel streams (U tile, C tile) pairs HBM -> VMEM under the
selected strategy while the previous pair is in the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   WriteBack, as_spec, compiler_params, emit,
                                   scratch_for, writeback_scratch)


# --- diagonal block factorization ---------------------------------------------

def _diag_kernel(a_ref, o_ref, *, bs: int):
    blk = a_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    for k in range(bs):
        pivot = blk[k, k]
        colk = jnp.where(rows[:, k] > k, blk[:, k] / pivot, blk[:, k])
        blk = blk.at[:, k].set(colk)
        mask = (rows > k) & (cols > k)
        blk = jnp.where(mask, blk - jnp.outer(colk, blk[k, :]), blk)
    o_ref[...] = blk


def lud_diagonal(block: jax.Array, *, interpret: bool = False) -> jax.Array:
    bs = block.shape[0]
    return pl.pallas_call(
        functools.partial(_diag_kernel, bs=bs),
        out_shape=jax.ShapeDtypeStruct((bs, bs), block.dtype),
        interpret=interpret,
    )(block)


# --- perimeter row: U_kj = L_kk^{-1} A_kj (unit lower, forward substitution) ---

def _perim_row_kernel(d_ref, a_ref, o_ref, *, bs: int):
    d = d_ref[...]
    strip = a_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    for r in range(1, bs):
        lrow = jnp.where(cols < r, d[r:r + 1, :], 0.0)      # L[r, :r]
        strip = strip.at[r:r + 1, :].add(
            -jnp.dot(lrow, strip, preferred_element_type=strip.dtype))
    o_ref[...] = strip


def lud_perimeter_row(diag: jax.Array, strip: jax.Array, *, bw: int = 128,
                      interpret: bool = False) -> jax.Array:
    bs, w = strip.shape
    bw = min(bw, w)
    assert w % bw == 0
    return pl.pallas_call(
        functools.partial(_perim_row_kernel, bs=bs),
        grid=(w // bw,),
        in_specs=[pl.BlockSpec((bs, bs), lambda j: (0, 0)),
                  pl.BlockSpec((bs, bw), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bs, bw), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bs, w), strip.dtype),
        interpret=interpret,
    )(diag, strip)


# --- perimeter column: L_ik = A_ik U_kk^{-1} (upper, non-unit) -----------------

def _perim_col_kernel(d_ref, a_ref, o_ref, *, bs: int):
    d = d_ref[...]
    strip = a_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
    for c in range(bs):
        ucol = jnp.where(rows < c, d[:, c:c + 1], 0.0)      # U[:c, c]
        newcol = (strip[:, c:c + 1]
                  - jnp.dot(strip, ucol, preferred_element_type=strip.dtype)
                  ) / d[c, c]
        strip = strip.at[:, c:c + 1].set(newcol)
    o_ref[...] = strip


def lud_perimeter_col(diag: jax.Array, strip: jax.Array, *, bh: int = 128,
                      interpret: bool = False) -> jax.Array:
    h, bs = strip.shape
    bh = min(bh, h)
    assert h % bh == 0
    return pl.pallas_call(
        functools.partial(_perim_col_kernel, bs=bs),
        grid=(h // bh,),
        in_specs=[pl.BlockSpec((bs, bs), lambda i: (0, 0)),
                  pl.BlockSpec((bh, bs), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bh, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, bs), strip.dtype),
        interpret=interpret,
    )(diag, strip)


# --- internal trailing update: C -= L @ U, streamed -----------------------------

def _internal_kernel(l_hbm, u_hbm, c_hbm, o_hbm, l_buf, u_buf, c_buf, out_buf,
                     u_stage, c_stage, l_sem, u_sems, c_sems, out_sems,
                     *, spec: PipelineSpec, n_tiles: int, bi: int, bs: int,
                     bj: int):
    ii = pl.program_id(0)
    lc = pltpu.make_async_copy(l_hbm.at[pl.ds(ii * bi, bi), :], l_buf, l_sem)
    lc.start()

    u_stream = TileStream(
        hbm=u_hbm, vmem=u_buf, sem=u_sems,
        index=lambda j: (slice(None), pl.ds(j * bj, bj)),
        depth=spec.ring_depth)
    c_stream = TileStream(
        hbm=c_hbm, vmem=c_buf, sem=c_sems,
        index=lambda j: (pl.ds(ii * bi, bi), pl.ds(j * bj, bj)),
        depth=spec.ring_depth)
    wb = WriteBack(
        hbm=o_hbm, vmem=out_buf, sem=out_sems,
        index=lambda j: (pl.ds(ii * bi, bi), pl.ds(j * bj, bj)),
        depth=spec.out_depth)
    lc.wait()
    l_tile = l_buf[...]

    def update(j, u_tile, c_tile):
        wb.push(j, c_tile - jnp.dot(l_tile, u_tile,
                                    preferred_element_type=c_tile.dtype))

    if spec.strategy == Strategy.DROP_OFF:
        emit(spec, [u_stream, c_stream], n_tiles,
             lambda j, vals: update(j, vals[0], vals[1]))
    else:
        def compute(j, bufs):
            update(j, bufs[0][...], bufs[1][...])
        emit(spec, [u_stream, c_stream], n_tiles, compute,
             staging=[u_stage, c_stage])
    wb.drain(n_tiles)


def lud_internal(l_strip: jax.Array, u_strip: jax.Array, c: jax.Array, *,
                 spec: PipelineSpec = PipelineSpec(), bi: int = 128,
                 bj: int = 128, interpret: bool = False) -> jax.Array:
    """C -= L @ U.  l_strip: (H, bs), u_strip: (bs, W), c: (H, W)."""
    spec = as_spec(spec)
    (h, bs), (_, w) = l_strip.shape, u_strip.shape
    bi, bj = min(bi, h), min(bj, w)
    assert h % bi == 0 and w % bj == 0
    u_buf, u_sems, u_stage = scratch_for(spec, (bs, bj), u_strip.dtype)
    c_buf, c_sems, c_stage = scratch_for(spec, (bi, bj), c.dtype)
    out_buf, out_sems = writeback_scratch(spec, (bi, bj), c.dtype)
    kernel = functools.partial(
        _internal_kernel, spec=spec, n_tiles=w // bj, bi=bi, bs=bs, bj=bj)
    return pl.pallas_call(
        kernel,
        grid=(h // bi,),
        out_shape=jax.ShapeDtypeStruct((h, w), c.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 3,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((bi, bs), l_strip.dtype),
            u_buf, c_buf,
            out_buf,
            u_stage,
            c_stage,
            pltpu.SemaphoreType.DMA,
            u_sems, c_sems, out_sems,
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",)),
    )(l_strip, u_strip, c)


# --- full blocked LUD ------------------------------------------------------------

def lud_pallas(a: jax.Array, *, bs: int = 32,
               spec: PipelineSpec = PipelineSpec(),
               interpret: bool = False) -> jax.Array:
    """Blocked LU of (n, n) with n % bs == 0.  Returns the combined LU matrix
    (matches ref.lud_ref)."""
    spec = as_spec(spec)
    n = a.shape[0]
    if n % bs or bs > n:
        raise ValueError(f"n={n} not divisible by block size bs={bs}")
    nb = n // bs
    for k in range(nb):
        lo, hi = k * bs, (k + 1) * bs
        diag = lud_diagonal(a[lo:hi, lo:hi], interpret=interpret)
        a = a.at[lo:hi, lo:hi].set(diag)
        if k == nb - 1:
            break
        row = lud_perimeter_row(diag, a[lo:hi, hi:], interpret=interpret)
        col = lud_perimeter_col(diag, a[hi:, lo:hi], interpret=interpret)
        a = a.at[lo:hi, hi:].set(row)
        a = a.at[hi:, lo:hi].set(col)
        c = lud_internal(col, row, a[hi:, hi:], spec=spec, interpret=interpret)
        a = a.at[hi:, hi:].set(c)
    return a
