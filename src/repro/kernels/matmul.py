"""MXU-tiled matmul with an explicit overlap-k HBM->VMEM pipeline.

This is the paper's Overlap pattern applied to the TPU's dominant compute
kernel: A (M,K) x B (K,N) accumulates over K tiles while the next K tile of
both operands streams in.  Block shapes default to MXU-aligned 128 multiples;
accumulation is fp32 regardless of input dtype.

Grid: (M//bm, N//bn); the K loop runs inside the kernel under the selected
strategy so the DMA/compute overlap is explicit (not left to the pallas_call
grid pipeliner), mirroring the paper's hand-written pipelines.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   as_spec, compiler_params, emit,
                                   scratch_for)


def _matmul_kernel(a_hbm, b_hbm, o_hbm, a_buf, b_buf, acc, a_stage, b_stage,
                   a_sems, b_sems, out_sem,
                   *, spec: PipelineSpec, n_k: int, bm: int, bk: int, bn: int):
    mi = pl.program_id(0)
    ni = pl.program_id(1)

    a_stream = TileStream(
        hbm=a_hbm, vmem=a_buf, sem=a_sems,
        index=lambda k: (pl.ds(mi * bm, bm), pl.ds(k * bk, bk)),
        depth=spec.ring_depth)
    b_stream = TileStream(
        hbm=b_hbm, vmem=b_buf, sem=b_sems,
        index=lambda k: (pl.ds(k * bk, bk), pl.ds(ni * bn, bn)),
        depth=spec.ring_depth)

    acc[...] = jnp.zeros_like(acc)

    def mac(a_tile, b_tile):
        acc[...] += jnp.dot(a_tile, b_tile,
                            preferred_element_type=jnp.float32)

    if spec.strategy == Strategy.DROP_OFF:
        emit(spec, [a_stream, b_stream], n_k,
             lambda k, vals: mac(vals[0], vals[1]))
    else:
        def compute(k, bufs):
            mac(bufs[0][...], bufs[1][...])
        emit(spec, [a_stream, b_stream], n_k, compute,
             staging=[a_stage, b_stage])

    # drain accumulator to HBM
    out = pltpu.make_async_copy(
        acc, o_hbm.at[pl.ds(mi * bm, bm), pl.ds(ni * bn, bn)], out_sem)
    out.start()
    out.wait()


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  spec: PipelineSpec = PipelineSpec(),
                  bm: int = 128, bk: int = 128, bn: int = 128,
                  interpret: bool = False) -> jax.Array:
    """a: (M, K), b: (K, N) -> fp32 (M, N).  Dims must divide block shapes."""
    spec = as_spec(spec)
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    if m % bm or k % bk or n % bn:
        raise ValueError(f"shape {(m, k, n)} not divisible by blocks {(bm, bk, bn)}")
    n_k = k // bk
    a_buf, a_sems, a_stage = scratch_for(spec, (bm, bk), a.dtype)
    b_buf, b_sems, b_stage = scratch_for(spec, (bk, bn), b.dtype)
    kernel = functools.partial(
        _matmul_kernel, spec=spec, n_k=n_k, bm=bm, bk=bk, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            a_buf, b_buf,
            pltpu.VMEM((bm, bn), jnp.float32),   # accumulator
            a_stage,
            b_stage,
            a_sems, b_sems,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(a, b)


def matmul_vmem_bytes(strategy: Strategy, bm: int, bk: int, bn: int,
                      depth: int, itemsize: int = 2) -> int:
    """VMEM footprint claimed by the block shapes (for the low-occupancy
    analysis: footprint bounds how many programs can co-schedule)."""
    d = 1 if strategy in (Strategy.SYNC, Strategy.REGISTER_BYPASS) else depth
    buf = d * (bm * bk + bk * bn) * itemsize
    stage = (bm * bk + bk * bn) * itemsize if strategy == Strategy.SYNC else 0
    return buf + stage + bm * bn * 4
