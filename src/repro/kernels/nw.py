"""Needleman-Wunsch (Rodinia NW) as a Pallas TPU kernel.

Rodinia processes the DP table in 16x16 blocks along anti-diagonals (a GPU
shared-memory shape).  On TPU we instead *vectorise the row recurrence*:

    m[i,j] = max(m[i-1,j-1] + s[i-1,j-1],  m[i,j-1] - p,  m[i-1,j] - p)

Splitting off c[j] = max(m[i-1,j-1] + s[..], m[i-1,j] - p) leaves
m[i,j] = max(c[j], m[i,j-1] - p) = max_{k<=j} (c[k] - (j-k) p), a max-plus
prefix scan: with t = c + j*p, m = cummax(t) - j*p.  The cummax runs as a
log2(n) Hillis-Steele ladder of vector ops — a full row per step on the VPU
instead of a 16-wide anti-diagonal.  This is the "rethink the algorithm for
the memory hierarchy" adaptation: rows stream HBM -> VMEM under the paper's
async strategies (NW favoured Register Bypass on A100, 1.01-1.08x) and the
DP state lives in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   WriteBack, as_spec, emit, scratch_for,
                                   writeback_scratch)

NEG = -1e30


def _cummax(x):
    """Hillis-Steele inclusive max-scan along the last axis (static width)."""
    n = x.shape[-1]
    shift = 1
    while shift < n:
        shifted = jnp.concatenate(
            [jnp.full_like(x[..., :shift], NEG), x[..., :-shift]], axis=-1)
        x = jnp.maximum(x, shifted)
        shift *= 2
    return x


def _nw_kernel(scores_hbm, o_hbm, state, row_buf, stage, sems, out_buf,
               out_sems, init_sem,
               *, spec: PipelineSpec, n_tiles: int, tile_rows: int, n: int,
               width: int, penalty: float):
    # state = DP row of length n+1 (padded to `width`); row 0 is -j*p
    j = jax.lax.broadcasted_iota(jnp.float32, (1, width), 1)
    valid = j <= n
    state[...] = jnp.where(valid, -penalty * j, NEG)

    stream = TileStream(
        hbm=scores_hbm, vmem=row_buf, sem=sems,
        index=lambda i: (pl.ds(i * tile_rows, tile_rows), slice(None)),
        depth=spec.ring_depth)
    wb = WriteBack(
        hbm=o_hbm, vmem=out_buf, sem=out_sems,
        index=lambda i: (pl.ds(i * tile_rows, tile_rows), slice(None)),
        depth=spec.out_depth)

    def fold(i, tile):
        # tile: (tile_rows, width) score rows s[i-1, j-1] pre-aligned to j
        rows = []
        for r in range(tile_rows):                  # carried row recurrence
            row_idx = (i * tile_rows + r + 1)
            prev = state[...]
            prev_shift = jnp.concatenate(
                [jnp.full_like(prev[:, :1], NEG), prev[:, :-1]], axis=1)
            c = jnp.maximum(prev_shift + tile[r:r + 1, :], prev - penalty)
            c = jnp.where(j == 0, -penalty * row_idx, c)
            t = jnp.where(valid, c + penalty * j, NEG)
            new = jnp.where(valid, _cummax(t) - penalty * j, NEG)
            state[...] = new
            rows.append(new)
        wb.push(i, jnp.concatenate(rows, axis=0))

    if spec.strategy == Strategy.DROP_OFF:
        emit(spec, [stream], n_tiles, lambda i, vals: fold(i, vals[0]))
    else:
        def compute(i, bufs):
            fold(i, bufs[0][...])
        emit(spec, [stream], n_tiles, compute, staging=[stage])

    wb.drain(n_tiles)


def nw_pallas(seq_scores: jax.Array, penalty: int, *,
              spec: PipelineSpec = PipelineSpec(Strategy.REGISTER_BYPASS),
              tile_rows: int = 8,
              interpret: bool = False) -> jax.Array:
    """seq_scores: (n, n) similarity matrix.  Returns the (n+1, n+1) DP table
    (float32), matching ref.nw_ref."""
    spec = as_spec(spec)
    n = seq_scores.shape[0]
    if n % tile_rows:
        raise ValueError(f"n={n} must divide tile_rows={tile_rows}")
    width = ((n + 1 + 127) // 128) * 128
    # align scores so that column j of the padded row holds s[i-1, j-1]
    scores = jnp.pad(seq_scores.astype(jnp.float32),
                     ((0, 0), (1, width - n - 1)))
    n_tiles = n // tile_rows
    row_buf, sems, stage = scratch_for(spec, (tile_rows, width), jnp.float32)
    out_buf, out_sems = writeback_scratch(spec, (tile_rows, width),
                                          jnp.float32)
    kernel = functools.partial(
        _nw_kernel, spec=spec, n_tiles=n_tiles, tile_rows=tile_rows,
        n=n, width=width, penalty=float(penalty))
    table = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, width), jnp.float32),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((1, width), jnp.float32),           # DP row state
            row_buf,
            stage,
            sems,
            out_buf,
            out_sems,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(scores)
    top = -penalty * jnp.arange(n + 1, dtype=jnp.float32)[None, :]
    return jnp.concatenate([top, table[:, :n + 1]], axis=0)
