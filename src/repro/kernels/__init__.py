"""Pallas TPU kernels implementing the paper's async-copy strategies on the
compute hot spots: the §4.1 stream microbenchmark, the four async-amenable
Rodinia benchmarks (Hotspot, Pathfinder, NW, LUD), and the two transformer
hot kernels (tiled matmul, flash attention).

Layout per the house style: ``<name>.py`` holds the ``pl.pallas_call`` +
BlockSpec kernel, ``ops.py`` the jit'd wrappers, ``ref.py`` the pure-jnp
oracles.
"""
from . import ops, ref
from ..core.async_pipeline import Strategy

__all__ = ["ops", "ref", "Strategy"]
