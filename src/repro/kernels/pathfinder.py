"""Rodinia Pathfinder (grid DP) as a Pallas TPU kernel.

dst[j] = wall[r, j] + min(prev[j-1], prev[j], prev[j+1]), rows carried
sequentially.  The row recurrence cannot be parallelised, which is exactly the
paper's low-occupancy situation: the win comes from prefetching the *next* row
tile while the current one is folded into the DP state (the paper found this
benchmark amenable only to the Drop-Off pattern, 1.04-1.11x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   as_spec, emit, scratch_for)


def _min3(prev):
    # prev: (1, cols); neighbours clamp at the edges
    left = jnp.concatenate([prev[:, :1], prev[:, :-1]], axis=1)
    right = jnp.concatenate([prev[:, 1:], prev[:, -1:]], axis=1)
    return jnp.minimum(prev, jnp.minimum(left, right))


def _pathfinder_kernel(wall_hbm, o_hbm, state, row_buf, stage, sems, out_sem,
                       *, spec: PipelineSpec, n_tiles: int, tile_rows: int):
    # row 0 initialises the DP state
    init = pltpu.make_async_copy(wall_hbm.at[pl.ds(0, 1), :], state, out_sem)
    init.start()
    init.wait()

    stream = TileStream(
        hbm=wall_hbm, vmem=row_buf, sem=sems,
        index=lambda i: (pl.ds(1 + i * tile_rows, tile_rows), slice(None)),
        depth=spec.ring_depth)

    def fold(tile):
        for r in range(tile_rows):          # static unroll; carried dependency
            state[...] = tile[r:r + 1, :] + _min3(state[...])

    if spec.strategy == Strategy.DROP_OFF:
        emit(spec, [stream], n_tiles, lambda i, vals: fold(vals[0]))
    else:
        def compute(i, bufs):
            fold(bufs[0][...])
        emit(spec, [stream], n_tiles, compute, staging=[stage])

    out = pltpu.make_async_copy(state, o_hbm, out_sem)
    out.start()
    out.wait()


def pathfinder_pallas(wall: jax.Array, *,
                      spec: PipelineSpec = PipelineSpec(Strategy.DROP_OFF),
                      tile_rows: int = 8,
                      interpret: bool = False) -> jax.Array:
    """wall: (rows, cols); rows-1 must divide by tile_rows.  Returns (1, cols)
    final DP row."""
    spec = as_spec(spec)
    rows, cols = wall.shape
    if (rows - 1) % tile_rows:
        raise ValueError(f"rows-1={rows-1} must divide tile_rows={tile_rows}")
    n_tiles = (rows - 1) // tile_rows
    row_buf, sems, stage = scratch_for(spec, (tile_rows, cols), wall.dtype)
    kernel = functools.partial(
        _pathfinder_kernel, spec=spec, n_tiles=n_tiles, tile_rows=tile_rows)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((1, cols), wall.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((1, cols), wall.dtype),          # DP state
            row_buf,
            stage,
            sems,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(wall)
