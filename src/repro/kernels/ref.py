"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels/tests assert against
(``np.testing.assert_allclose``); they are deliberately written in the most
obvious way, with no tiling or performance tricks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --- stream (paper §4.1 microbenchmark) -------------------------------------

def stream_ref(x: jax.Array, iters: int = 1) -> jax.Array:
    for _ in range(iters):
        x = x * 0.5 + 0.5
    return x


# --- hotspot (Rodinia 5-point thermal stencil) -------------------------------

def hotspot_ref(temp: jax.Array, power: jax.Array, *, iters: int,
                rx: float = 0.1, ry: float = 0.1, rz: float = 0.5,
                cap: float = 0.5) -> jax.Array:
    """temp, power: (R, C).  Edge cells clamp (replicate padding), matching
    the Rodinia boundary treatment."""
    def step(t, _):
        up = jnp.concatenate([t[:1], t[:-1]], axis=0)
        down = jnp.concatenate([t[1:], t[-1:]], axis=0)
        left = jnp.concatenate([t[:, :1], t[:, :-1]], axis=1)
        right = jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)
        delta = cap * (power + (up + down - 2.0 * t) * ry
                       + (left + right - 2.0 * t) * rx
                       + (80.0 - t) * rz)
        return t + delta, None
    out, _ = jax.lax.scan(step, temp, None, length=iters)
    return out


# --- pathfinder (Rodinia row-wise DP) ----------------------------------------

def pathfinder_ref(wall: jax.Array) -> jax.Array:
    """wall: (rows, cols) int32 costs.  dst[j] = wall[r,j] + min(prev[j-1],
    prev[j], prev[j+1]); edges clamp.  Returns the final row of path costs."""
    def step(prev, row):
        left = jnp.concatenate([prev[:1], prev[:-1]])
        right = jnp.concatenate([prev[1:], prev[-1:]])
        return row + jnp.minimum(prev, jnp.minimum(left, right)), None
    out, _ = jax.lax.scan(step, wall[0], wall[1:])
    return out


# --- needleman-wunsch (Rodinia NW) -------------------------------------------

def nw_ref(seq_scores: jax.Array, penalty: int) -> jax.Array:
    """seq_scores: (n, n) similarity matrix (Rodinia precomputes this as
    reference[i,j]).  Returns the (n+1, n+1) DP table with first row/col
    initialised to -i*penalty, filled with
        M[i,j] = max(M[i-1,j-1] + s[i-1,j-1], M[i,j-1] - p, M[i-1,j] - p).
    Computed anti-diagonally with a scan (still O(n^2) work)."""
    n = seq_scores.shape[0]
    m = jnp.zeros((n + 1, n + 1), dtype=seq_scores.dtype)
    m = m.at[0, :].set(-penalty * jnp.arange(n + 1, dtype=seq_scores.dtype))
    m = m.at[:, 0].set(-penalty * jnp.arange(n + 1, dtype=seq_scores.dtype))

    def row_step(m, i):
        def col_step(m, j):
            v = jnp.maximum(
                m[i - 1, j - 1] + seq_scores[i - 1, j - 1],
                jnp.maximum(m[i, j - 1] - penalty, m[i - 1, j] - penalty))
            return m.at[i, j].set(v), None
        m, _ = jax.lax.scan(col_step, m, jnp.arange(1, n + 1))
        return m, None
    m, _ = jax.lax.scan(row_step, m, jnp.arange(1, n + 1))
    return m


# --- LU decomposition (Rodinia LUD) ------------------------------------------

def lud_ref(a: jax.Array) -> jax.Array:
    """In-place Doolittle LU (no pivoting), matching Rodinia's lud kernel:
    returns combined LU matrix where U is the upper triangle (incl. diagonal)
    and L the strict lower triangle (unit diagonal implied)."""
    n = a.shape[0]
    def outer(a, k):
        pivot = a[k, k]
        col = jnp.where(jnp.arange(n) > k, a[:, k] / pivot, a[:, k])
        a = a.at[:, k].set(col)
        row_mask = (jnp.arange(n)[:, None] > k) & (jnp.arange(n)[None, :] > k)
        update = jnp.outer(col, a[k, :])
        a = jnp.where(row_mask, a - update, a)
        return a, None
    a, _ = jax.lax.scan(outer, a, jnp.arange(n))
    return a


# --- matmul -------------------------------------------------------------------

def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


# --- flash attention ----------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None,
                  window: int = 0) -> jax.Array:
    """q,k,v: (heads, seq, head_dim) -> (heads, seq, head_dim), fp32 math."""
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("hqd,hkd->hqk", q * scale, k)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)
