"""Rodinia Hotspot (2D thermal 5-point stencil) as a Pallas TPU kernel.

One kernel call performs one simulation step over an (R, C) grid.  The host
wrapper replicate-pads the temperature field to (R+2, C+2); the kernel streams
row bands with a 2-row halo HBM -> VMEM under the selected async-copy strategy
(the paper finds Overlap the winning pattern here, 1.12-1.23x on A100) and
drains results through an N-deep write-back ring.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   WriteBack, as_spec, compiler_params, emit,
                                   scratch_for, writeback_scratch)


def _hotspot_kernel(tpad_hbm, power_hbm, o_hbm, t_buf, p_buf, out_buf,
                    t_stage, p_stage, t_sems, p_sems, out_sems,
                    *, spec: PipelineSpec, n_tiles: int, tile_rows: int,
                    cols: int, rx: float, ry: float, rz: float, cap: float):
    pid = pl.program_id(0)
    base = pid * n_tiles * tile_rows

    t_stream = TileStream(
        hbm=tpad_hbm, vmem=t_buf, sem=t_sems,
        index=lambda i: (pl.ds(base + i * tile_rows, tile_rows + 2),
                         slice(None)),
        depth=spec.ring_depth)
    p_stream = TileStream(
        hbm=power_hbm, vmem=p_buf, sem=p_sems,
        index=lambda i: (pl.ds(base + i * tile_rows, tile_rows), slice(None)),
        depth=spec.ring_depth)
    wb = WriteBack(
        hbm=o_hbm, vmem=out_buf, sem=out_sems,
        index=lambda i: (pl.ds(base + i * tile_rows, tile_rows), slice(None)),
        depth=spec.out_depth)

    def stencil(tpad, power):
        # tpad: (tile_rows+2, cols+2) halo tile; power: (tile_rows, cols)
        t = tpad[1:-1, 1:-1]
        up = tpad[:-2, 1:-1]
        down = tpad[2:, 1:-1]
        left = tpad[1:-1, :-2]
        right = tpad[1:-1, 2:]
        delta = cap * (power + (up + down - 2.0 * t) * ry
                       + (left + right - 2.0 * t) * rx
                       + (80.0 - t) * rz)
        return t + delta

    if spec.strategy == Strategy.DROP_OFF:
        def compute_value(i, vals):
            wb.push(i, stencil(vals[0], vals[1]))
        emit(spec, [t_stream, p_stream], n_tiles, compute_value)
    else:
        def compute(i, bufs):
            wb.push(i, stencil(bufs[0][...], bufs[1][...]))
        emit(spec, [t_stream, p_stream], n_tiles, compute,
             staging=[t_stage, p_stage])

    wb.drain(n_tiles)


def hotspot_step_pallas(temp: jax.Array, power: jax.Array, *,
                        spec: PipelineSpec = PipelineSpec(),
                        tile_rows: int = 8,
                        rx: float = 0.1, ry: float = 0.1, rz: float = 0.5,
                        cap: float = 0.5, grid: int = 1,
                        interpret: bool = False) -> jax.Array:
    """One hotspot iteration.  temp/power: (R, C); R divisible by
    grid*tile_rows."""
    spec = as_spec(spec)
    rows, cols = temp.shape
    block = rows // grid
    if rows % (grid * tile_rows):
        raise ValueError(f"rows={rows} not divisible by grid*tile_rows")
    n_tiles = block // tile_rows
    tpad = jnp.pad(temp, ((1, 1), (1, 1)), mode="edge")

    t_buf, t_sems, t_stage = scratch_for(spec, (tile_rows + 2, cols + 2),
                                         temp.dtype)
    p_buf, p_sems, p_stage = scratch_for(spec, (tile_rows, cols), power.dtype)
    out_buf, out_sems = writeback_scratch(spec, (tile_rows, cols), temp.dtype)
    kernel = functools.partial(
        _hotspot_kernel, spec=spec, n_tiles=n_tiles,
        tile_rows=tile_rows, cols=cols, rx=rx, ry=ry, rz=rz, cap=cap)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        out_shape=jax.ShapeDtypeStruct((rows, cols), temp.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            t_buf, p_buf, out_buf,
            t_stage, p_stage,
            t_sems, p_sems, out_sems,
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("arbitrary",)),
    )(tpad, power)


def hotspot_pallas(temp: jax.Array, power: jax.Array, *, iters: int,
                   **kw) -> jax.Array:
    for _ in range(iters):
        temp = hotspot_step_pallas(temp, power, **kw)
    return temp
