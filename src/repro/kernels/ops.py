"""Public jit'd wrappers around the Pallas kernels.

These are the entry points the framework / benchmarks / tests use.  Every
wrapper accepts ``strategy`` (the paper's async-copy pattern) plus the
pipeline-shape axes ``depth`` / ``wait_group`` (and ``out_depth`` for the
kernels with a write-back ring), is jitted with the structural arguments
static, and has a matching oracle in ``ref.py``.  The flat keywords are
assembled into a ``core.async_pipeline.PipelineSpec`` inside the jitted
implementation.  ``interpret=True`` (default on this CPU container) runs the
kernel bodies in Python via the Pallas interpreter; on a real TPU pass
``interpret=False``.

Config constants are NOT hard-coded per call site: each kernel's tunable
parameters live in ``KERNEL_DEFAULTS`` and any omitted (None) keyword falls
back to that table.  The autotuner (``repro.tuning``) overwrites the table
via ``set_default_config`` with registry winners, so tuned configs flow to
every caller without touching call sites; explicit keywords still win.
"""
from __future__ import annotations

import functools
import logging
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..core.async_pipeline import PipelineSpec, Strategy
from . import flash_attention as _fa
from . import hotspot as _hs
from . import lud as _lud
from . import matmul as _mm
from . import nw as _nw
from . import pathfinder as _pf
from . import stream as _st

log = logging.getLogger("repro.kernels")

__all__ = [
    "stream", "hotspot", "pathfinder", "nw", "lud", "matmul",
    "flash_attention", "Strategy", "KERNEL_DEFAULTS", "default_config",
    "seed_default_config", "set_default_config", "reset_default_configs",
]


#: The single source of per-kernel tunable constants (the seed's hard-coded
#: values).  ``repro.tuning.apply_registry_defaults`` replaces entries with
#: empirically-tuned winners.  ``wait_group=None`` means the deepest safe
#: issue-ahead (depth - 1); ``out_depth`` is the write-back ring depth for
#: the kernels that drain through a WriteBack.
KERNEL_DEFAULTS: Dict[str, Dict[str, Any]] = {
    "stream": dict(strategy=Strategy.OVERLAP, tile_rows=8, n_tiles=4,
                   depth=2, wait_group=None, out_depth=2),
    "hotspot": dict(strategy=Strategy.OVERLAP, tile_rows=8, depth=2,
                    wait_group=None, out_depth=2),
    "pathfinder": dict(strategy=Strategy.DROP_OFF, tile_rows=8, depth=2,
                       wait_group=None),
    "nw": dict(strategy=Strategy.REGISTER_BYPASS, tile_rows=8, depth=2,
               wait_group=None, out_depth=2),
    "lud": dict(strategy=Strategy.OVERLAP, bs=32, depth=2, wait_group=None,
                out_depth=2),
    "matmul": dict(strategy=Strategy.OVERLAP, bm=128, bk=128, bn=128,
                   depth=2, wait_group=None),
    "flash_attention": dict(strategy=Strategy.OVERLAP, bq=128, bk=128,
                            depth=2, wait_group=None),
}

_SEED_DEFAULTS = {k: dict(v) for k, v in KERNEL_DEFAULTS.items()}


def default_config(kernel: str) -> Dict[str, Any]:
    """A copy of the current default config for ``kernel``."""
    return dict(KERNEL_DEFAULTS[kernel])


def seed_default_config(kernel: str) -> Dict[str, Any]:
    """The original hard-coded config, regardless of installed tunings."""
    return dict(_SEED_DEFAULTS[kernel])


def set_default_config(kernel: str, **config: Any) -> Dict[str, Any]:
    """Overwrite default constants for ``kernel`` (tuner integration point).

    Unknown keys are rejected so a stale registry cannot inject parameters
    a kernel does not understand."""
    cur = KERNEL_DEFAULTS[kernel]
    unknown = set(config) - set(cur)
    if unknown:
        raise KeyError(f"unknown config keys for {kernel}: {sorted(unknown)}")
    cur.update(config)
    return dict(cur)


def reset_default_configs() -> None:
    """Restore the seed defaults (tests / benchmark baselines)."""
    for k, v in _SEED_DEFAULTS.items():
        KERNEL_DEFAULTS[k] = dict(v)


def _resolve(kernel: str, **given: Any) -> Dict[str, Any]:
    cfg = KERNEL_DEFAULTS[kernel]
    return {k: (cfg[k] if v is None else v) for k, v in given.items()}


def _with_seed_fallback(kernel: str, given: Dict[str, Any],
                        call: Callable[[Dict[str, Any]], Any]):
    """Run ``call`` with defaults-resolved config; if a *tuned* default is
    structurally invalid for this problem (tile does not divide the shape,
    raising ValueError), retry once with the seed constants.

    Tuned installs are per-(large)-shape winners promoted to process-wide
    defaults; a smaller call shape must degrade to the seed config, not
    crash.  Explicitly-passed (non-None) parameters are never overridden —
    a user error still raises."""
    cfg = _resolve(kernel, **given)
    seed = {k: (_SEED_DEFAULTS[kernel][k] if v is None else v)
            for k, v in given.items()}
    try:
        return call(cfg)
    except ValueError:
        if cfg == seed:
            raise
        log.warning("tuned %s config %s invalid for this shape; "
                    "falling back to seed defaults", kernel,
                    {k: v for k, v in cfg.items() if given[k] is None})
        return call(seed)


# ---------------------------------------------------------------------------
# jit'd implementations (explicit static config) + resolving wrappers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "iters", "strategy", "tile_rows", "n_tiles", "depth", "wait_group",
    "out_depth", "interpret"))
def _stream(x, *, iters, strategy, tile_rows, n_tiles, depth, wait_group,
            out_depth, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group, out_depth=out_depth)
    return _st.stream_pallas(x, iters=iters, spec=spec, tile_rows=tile_rows,
                             n_tiles=n_tiles, interpret=interpret)


def stream(x, *, iters=1, strategy=None, tile_rows=None, n_tiles=None,
           depth=None, wait_group=None, out_depth=None, interpret=True):
    return _with_seed_fallback(
        "stream", dict(strategy=strategy, tile_rows=tile_rows,
                       n_tiles=n_tiles, depth=depth, wait_group=wait_group,
                       out_depth=out_depth),
        lambda cfg: _stream(x, iters=iters, interpret=interpret, **cfg))


@functools.partial(jax.jit, static_argnames=(
    "iters", "strategy", "tile_rows", "depth", "wait_group", "out_depth",
    "grid", "interpret"))
def _hotspot(temp, power, *, iters, strategy, tile_rows, depth, wait_group,
             out_depth, grid, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group, out_depth=out_depth)
    return _hs.hotspot_pallas(temp, power, iters=iters, spec=spec,
                              tile_rows=tile_rows, grid=grid,
                              interpret=interpret)


def hotspot(temp, power, *, iters=1, strategy=None, tile_rows=None,
            depth=None, wait_group=None, out_depth=None, grid=1,
            interpret=True):
    return _with_seed_fallback(
        "hotspot", dict(strategy=strategy, tile_rows=tile_rows, depth=depth,
                        wait_group=wait_group, out_depth=out_depth),
        lambda cfg: _hotspot(temp, power, iters=iters, grid=grid,
                             interpret=interpret, **cfg))


@functools.partial(jax.jit, static_argnames=(
    "strategy", "tile_rows", "depth", "wait_group", "interpret"))
def _pathfinder(wall, *, strategy, tile_rows, depth, wait_group, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group)
    return _pf.pathfinder_pallas(wall, spec=spec, tile_rows=tile_rows,
                                 interpret=interpret)


def pathfinder(wall, *, strategy=None, tile_rows=None, depth=None,
               wait_group=None, interpret=True):
    return _with_seed_fallback(
        "pathfinder", dict(strategy=strategy, tile_rows=tile_rows,
                           depth=depth, wait_group=wait_group),
        lambda cfg: _pathfinder(wall, interpret=interpret, **cfg))


@functools.partial(jax.jit, static_argnames=(
    "penalty", "strategy", "tile_rows", "depth", "wait_group", "out_depth",
    "interpret"))
def _nw_jit(seq_scores, *, penalty, strategy, tile_rows, depth, wait_group,
            out_depth, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group, out_depth=out_depth)
    return _nw.nw_pallas(seq_scores, penalty, spec=spec,
                         tile_rows=tile_rows, interpret=interpret)


def nw(seq_scores, *, penalty=10, strategy=None, tile_rows=None, depth=None,
       wait_group=None, out_depth=None, interpret=True):
    return _with_seed_fallback(
        "nw", dict(strategy=strategy, tile_rows=tile_rows, depth=depth,
                   wait_group=wait_group, out_depth=out_depth),
        lambda cfg: _nw_jit(seq_scores, penalty=penalty,
                            interpret=interpret, **cfg))


@functools.partial(jax.jit, static_argnames=(
    "bs", "strategy", "depth", "wait_group", "out_depth", "interpret"))
def _lud_jit(a, *, bs, strategy, depth, wait_group, out_depth, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group, out_depth=out_depth)
    return _lud.lud_pallas(a, bs=bs, spec=spec, interpret=interpret)


def lud(a, *, bs=None, strategy=None, depth=None, wait_group=None,
        out_depth=None, interpret=True):
    return _with_seed_fallback(
        "lud", dict(bs=bs, strategy=strategy, depth=depth,
                    wait_group=wait_group, out_depth=out_depth),
        lambda cfg: _lud_jit(a, interpret=interpret, **cfg))


@functools.partial(jax.jit, static_argnames=(
    "strategy", "bm", "bk", "bn", "depth", "wait_group", "interpret"))
def _matmul(a, b, *, strategy, bm, bk, bn, depth, wait_group, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group)
    return _mm.matmul_pallas(a, b, spec=spec, bm=bm, bk=bk, bn=bn,
                             interpret=interpret)


def matmul(a, b, *, strategy=None, bm=None, bk=None, bn=None, depth=None,
           wait_group=None, interpret=True):
    return _with_seed_fallback(
        "matmul", dict(strategy=strategy, bm=bm, bk=bk, bn=bn, depth=depth,
                       wait_group=wait_group),
        lambda cfg: _matmul(a, b, interpret=interpret, **cfg))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "strategy", "bq", "bk", "depth",
    "wait_group", "interpret"))
def _flash_jit(q, k, v, *, causal, window, scale, strategy, bq, bk, depth,
               wait_group, interpret):
    spec = PipelineSpec(strategy=strategy, depth=depth,
                        wait_group=wait_group)
    fn = functools.partial(
        _fa.flash_attention_pallas, causal=causal, window=window,
        scale=scale, spec=spec, bq=bq, bk=bk, interpret=interpret)
    for _ in range(q.ndim - 3):
        fn = jax.vmap(fn)
    return fn(q, k, v)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    strategy=None, bq=None, bk=None, depth=None,
                    wait_group=None, interpret=True):
    """q: (..., H, S, D), k/v: (..., KVH, S, D); leading dims are vmapped."""
    return _with_seed_fallback(
        "flash_attention", dict(strategy=strategy, bq=bq, bk=bk,
                                depth=depth, wait_group=wait_group),
        lambda cfg: _flash_jit(q, k, v, causal=causal, window=window,
                               scale=scale, interpret=interpret, **cfg))
