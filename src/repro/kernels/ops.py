"""Public jit'd wrappers around the Pallas kernels.

These are the entry points the framework / benchmarks / tests use.  Every
wrapper accepts ``strategy`` (the paper's async-copy pattern), is jitted with
the structural arguments static, and has a matching oracle in ``ref.py``.
``interpret=True`` (default on this CPU container) runs the kernel bodies in
Python via the Pallas interpreter; on a real TPU pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.async_pipeline import Strategy
from . import flash_attention as _fa
from . import hotspot as _hs
from . import lud as _lud
from . import matmul as _mm
from . import nw as _nw
from . import pathfinder as _pf
from . import stream as _st

__all__ = [
    "stream", "hotspot", "pathfinder", "nw", "lud", "matmul",
    "flash_attention", "Strategy",
]


@functools.partial(jax.jit, static_argnames=(
    "iters", "strategy", "tile_rows", "n_tiles", "depth", "interpret"))
def stream(x, *, iters=1, strategy=Strategy.OVERLAP, tile_rows=8, n_tiles=4,
           depth=2, interpret=True):
    return _st.stream_pallas(x, iters=iters, strategy=strategy,
                             tile_rows=tile_rows, n_tiles=n_tiles,
                             depth=depth, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "iters", "strategy", "tile_rows", "depth", "grid", "interpret"))
def hotspot(temp, power, *, iters=1, strategy=Strategy.OVERLAP, tile_rows=8,
            depth=2, grid=1, interpret=True):
    return _hs.hotspot_pallas(temp, power, iters=iters, strategy=strategy,
                              tile_rows=tile_rows, depth=depth, grid=grid,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "strategy", "tile_rows", "depth", "interpret"))
def pathfinder(wall, *, strategy=Strategy.DROP_OFF, tile_rows=8, depth=2,
               interpret=True):
    return _pf.pathfinder_pallas(wall, strategy=strategy,
                                 tile_rows=tile_rows, depth=depth,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "penalty", "strategy", "tile_rows", "depth", "interpret"))
def nw(seq_scores, *, penalty=10, strategy=Strategy.REGISTER_BYPASS,
       tile_rows=8, depth=2, interpret=True):
    return _nw.nw_pallas(seq_scores, penalty, strategy=strategy,
                         tile_rows=tile_rows, depth=depth,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "bs", "strategy", "depth", "interpret"))
def lud(a, *, bs=32, strategy=Strategy.OVERLAP, depth=2, interpret=True):
    return _lud.lud_pallas(a, bs=bs, strategy=strategy, depth=depth,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "strategy", "bm", "bk", "bn", "depth", "interpret"))
def matmul(a, b, *, strategy=Strategy.OVERLAP, bm=128, bk=128, bn=128,
           depth=2, interpret=True):
    return _mm.matmul_pallas(a, b, strategy=strategy, bm=bm, bk=bk, bn=bn,
                             depth=depth, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "strategy", "bq", "bk", "depth",
    "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    strategy=Strategy.OVERLAP, bq=128, bk=128, depth=2,
                    interpret=True):
    """q: (..., H, S, D), k/v: (..., KVH, S, D); leading dims are vmapped."""
    fn = functools.partial(
        _fa.flash_attention_pallas, causal=causal, window=window,
        scale=scale, strategy=strategy, bq=bq, bk=bk, depth=depth,
        interpret=interpret)
    if q.ndim == 3:
        return fn(q, k, v)
    for _ in range(q.ndim - 3):
        fn = jax.vmap(fn)
    return fn(q, k, v)
