"""Flash attention (online softmax) with async K/V streaming — the paper's
Overlap pattern applied to the transformer's dominant memory-bound kernel.

The K/V tiles for query block i+A stream HBM -> VMEM while block i is in the
MXU (A = the PipelineSpec's issue-ahead distance); causal/sliding-window
masking prunes the KV loop to the tiles that can contribute (traced loop
bounds).  GQA is handled by mapping each q head to its kv head inside the
grid.

Layout: q, k, v are (heads, seq, head_dim); batching is vmapped in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.async_pipeline import (PipelineSpec, Strategy, TileStream,
                                   as_spec, compiler_params, emit,
                                   scratch_for)

NEG_INF = -1e30


def _flash_kernel(q_hbm, k_hbm, v_hbm, o_hbm, q_buf, k_buf, v_buf, k_stage,
                  v_stage, acc, m_i, l_i, q_sem, k_sems, v_sems, out_sem,
                  *, spec: PipelineSpec, bq: int, bk: int, head_dim: int,
                  q_heads_per_kv: int, causal: bool, window: int,
                  scale: float, n_kv_tiles_max: int):
    qh = pl.program_id(0)
    qi = pl.program_id(1)
    kvh = qh // q_heads_per_kv
    q_start = qi * bq

    # ---- load the q tile (single DMA; it is reused across all KV tiles)
    qc = pltpu.make_async_copy(
        q_hbm.at[qh, pl.ds(q_start, bq), :], q_buf, q_sem)
    qc.start()

    # ---- KV tile range pruned by the mask structure
    if causal:
        hi = (q_start + bq + bk - 1) // bk          # tiles with kv_start <= q_end
        hi = jnp.minimum(hi, n_kv_tiles_max)
    else:
        hi = n_kv_tiles_max
    if window > 0:
        lo = jnp.maximum((q_start - window + 1) // bk, 0)
    else:
        lo = 0
    n_tiles = hi - lo

    k_stream = TileStream(
        hbm=k_hbm, vmem=k_buf, sem=k_sems,
        index=lambda i: (kvh, pl.ds((lo + i) * bk, bk), slice(None)),
        depth=spec.ring_depth)
    v_stream = TileStream(
        hbm=v_hbm, vmem=v_buf, sem=v_sems,
        index=lambda i: (kvh, pl.ds((lo + i) * bk, bk), slice(None)),
        depth=spec.ring_depth)

    acc[...] = jnp.zeros_like(acc)
    m_i[...] = jnp.full_like(m_i, NEG_INF)
    l_i[...] = jnp.zeros_like(l_i)
    qc.wait()
    q = q_buf[...].astype(jnp.float32) * scale

    def online_softmax(i, k_tile, v_tile):
        kv_start = (lo + i) * bk
        logits = jnp.dot(q, k_tile.astype(jnp.float32).T,
                         preferred_element_type=jnp.float32)  # (bq, bk)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kv_idx = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= kv_idx <= q_idx
        if window > 0:
            mask &= kv_idx > q_idx - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m_i[...], jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m_i[...] - m_new)
        p = jnp.exp(logits - m_new)
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jnp.dot(
            p, v_tile.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_i[...] = m_new

    if spec.strategy == Strategy.DROP_OFF:
        emit(spec, [k_stream, v_stream], n_tiles,
             lambda i, vals: online_softmax(i, vals[0], vals[1]))
    else:
        emit(spec, [k_stream, v_stream], n_tiles,
             lambda i, bufs: online_softmax(i, bufs[0][...], bufs[1][...]),
             staging=[k_stage, v_stage])

    out = (acc[...] / jnp.maximum(l_i[...], 1e-30)).astype(o_hbm.dtype)
    acc[...] = out
    oc = pltpu.make_async_copy(
        acc, o_hbm.at[qh, pl.ds(q_start, bq), :], out_sem)
    oc.start()
    oc.wait()


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           spec: PipelineSpec = PipelineSpec(),
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (H, S, D), k/v: (KVH, S, D) -> (H, S, D) fp32."""
    spec = as_spec(spec)
    h, s, d = q.shape
    kvh = k.shape[0]
    assert h % kvh == 0, (h, kvh)
    if s % bq or s % bk:
        raise ValueError(f"seq {s} must divide bq={bq}, bk={bk}")
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    k_buf, k_sems, k_stage = scratch_for(spec, (bk, d), k.dtype)
    v_buf, v_sems, v_stage = scratch_for(spec, (bk, d), v.dtype)
    kernel = functools.partial(
        _flash_kernel, spec=spec, bq=bq, bk=bk, head_dim=d,
        q_heads_per_kv=h // kvh, causal=causal, window=window, scale=scale,
        n_kv_tiles_max=s // bk)
    return pl.pallas_call(
        kernel,
        grid=(h, s // bq),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * 3,
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((bq, d), q.dtype),
            k_buf, v_buf,
            k_stage, v_stage,
            pltpu.VMEM((bq, d), jnp.float32),    # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.SemaphoreType.DMA,
            k_sems, v_sems,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(q, k, v)
