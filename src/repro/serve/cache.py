"""Paged KV cache: one fixed block arena + per-slot block tables.

The arena carves ``n_blocks`` blocks of ``block_len`` token rows per layer
out of a single global token budget (``models.transformer.PagedState``), so
serving never re-allocates a cache per prompt-length bucket.  Each batch
slot owns an ordered *block table*; because a slot fills its blocks
strictly in order, the gathered table is a dense per-slot cache view in
which row ``p`` holds position ``p`` — ``attend_decode``'s ``pos == -1``
masking (the same path ragged cohort serving uses) does the rest.

This module is the HOST side: a free-list allocator with
``alloc / append / free`` lifecycle plus admission accounting.  A request
admitted with ``admit()`` reserves its full lifetime block count up front
(prefill blocks are allocated immediately, decode blocks lazily as the
sequence crosses block boundaries), so a mid-decode allocation can never
deadlock the arena: if the blocks aren't guaranteed, admission refuses.

Block id 0 is a scratch block: inactive slots' decode writes land there
and unused table entries gather it with positions forced to -1, so stale
rows are never attended.  Freed blocks get their position rows cleared on
``free_slot`` for the same reason.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..models import transformer as tfm

__all__ = ["PagedKVCache", "next_pow2", "scatter_prefill"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


@partial(jax.jit, donate_argnums=(0,))
def _clear_pos(pos, ids):
    """Mark freed blocks' rows empty (ids padded with 0 = scratch block)."""
    return pos.at[ids].set(-1)


def scatter_prefill(paged: tfm.PagedState, k_dense, v_dense, pos_dense, ids
                    ) -> tfm.PagedState:
    """Scatter one request's dense prefill cache into its arena blocks.

    Pure (traceable) so schedulers can fuse it with the prefill forward
    into one jitted dispatch.  k/v_dense: (L, 1, bucket, KV, hd);
    pos_dense: (bucket,) with pad rows already -1; ids: (nb,) target
    block ids, nb * block_len == bucket."""
    L, _, bucket, kv, hd = k_dense.shape
    nb = ids.shape[0]
    bl = bucket // nb
    k = k_dense[:, 0].reshape(L, nb, bl, kv, hd)
    v = v_dense[:, 0].reshape(L, nb, bl, kv, hd)
    pos = pos_dense.reshape(nb, bl)
    return tfm.PagedState(k=paged.k.at[:, ids].set(k),
                          v=paged.v.at[:, ids].set(v),
                          pos=paged.pos.at[ids].set(pos))


class PagedKVCache:
    """Block-arena KV cache for ``batch`` slots under one token budget.

    ``total_tokens`` is the global arena budget (rounded up to whole
    blocks); ``max_seq`` bounds any single slot's length and sizes the
    block table width.  The device arena lives in ``self.state``
    (a ``models.transformer.PagedState``)."""

    def __init__(self, cfg: ArchConfig, batch: int, *, total_tokens: int,
                 max_seq: int, block_len: int = 16, dtype=None):
        if block_len < 1:
            raise ValueError("block_len must be >= 1")
        self.cfg = cfg
        self.batch = batch
        self.block_len = block_len
        self.max_blocks_per_slot = max(
            1, math.ceil(max_seq / block_len))
        self.max_seq = self.max_blocks_per_slot * block_len
        # +1: block 0 is the reserved scratch block, never allocated
        self.n_blocks = 1 + max(self.blocks_for(total_tokens),
                                self.max_blocks_per_slot)
        self.state = tfm.init_paged_state(cfg, self.n_blocks, block_len,
                                          dtype=dtype)
        # LIFO free list: a just-freed block is re-used first
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self.tables = np.full((batch, self.max_blocks_per_slot), -1,
                              np.int32)
        self._slot_blocks: List[List[int]] = [[] for _ in range(batch)]
        # blocks promised to admitted slots but not yet allocated
        self._slot_reserved = np.zeros((batch,), np.int64)
        self._write_fns = {}            # n_prefill_blocks -> jitted scatter
        # device copy of self.tables, re-uploaded only when tables change
        # (most decode steps allocate nothing, so the upload is elided)
        self._dev_tables: Optional[jax.Array] = None

    # -- accounting ---------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(max(int(n_tokens), 0) / self.block_len)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return int(self._slot_reserved.sum())

    @property
    def used_blocks(self) -> int:
        return sum(len(b) for b in self._slot_blocks)

    def can_admit(self, lifetime_tokens: int) -> bool:
        """Fit-by-free-blocks admission: the request's whole lifetime
        (prefill + planned decode) must fit in unreserved free blocks."""
        need = self.blocks_for(lifetime_tokens)
        return (need <= self.free_blocks - self.reserved_blocks
                and need <= self.max_blocks_per_slot)

    # -- lifecycle ----------------------------------------------------------

    def _alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"arena exhausted: need {n} blocks, {len(self._free)} free "
                f"(admission accounting bug)")
        return [self._free.pop() for _ in range(n)]

    def admit(self, slot: int, prefill_tokens: int,
              lifetime_tokens: int) -> List[int]:
        """Reserve ``lifetime_tokens`` worth of blocks for ``slot`` and
        allocate the prefill prefix now.  Returns the prefill block ids."""
        if self._slot_blocks[slot] or self._slot_reserved[slot]:
            raise RuntimeError(f"slot {slot} already admitted")
        if not self.can_admit(lifetime_tokens):
            raise RuntimeError(f"slot {slot}: admission check not honored")
        n_now = self.blocks_for(prefill_tokens)
        total = max(self.blocks_for(lifetime_tokens), n_now)
        ids = self._alloc(n_now)
        self._slot_blocks[slot] = list(ids)
        self.tables[slot, :n_now] = ids
        self._dev_tables = None
        self._slot_reserved[slot] = total - n_now
        return ids

    def append(self, slot: int, pos: int) -> None:
        """Ensure the block holding row ``pos`` exists before a decode
        write — allocates the slot's next block (from its reservation)
        when ``pos`` crosses a block boundary."""
        j = pos // self.block_len
        if j < len(self._slot_blocks[slot]):
            return
        if j != len(self._slot_blocks[slot]) or j >= self.max_blocks_per_slot:
            raise RuntimeError(
                f"slot {slot}: non-contiguous append at pos {pos}")
        if self._slot_reserved[slot] <= 0:
            raise RuntimeError(
                f"slot {slot}: append beyond reserved lifetime at pos {pos}")
        (bid,) = self._alloc(1)
        self._slot_blocks[slot].append(bid)
        self.tables[slot, j] = bid
        self._dev_tables = None
        self._slot_reserved[slot] -= 1

    def free_slot(self, slot: int) -> List[int]:
        """Return the slot's blocks to the free list (LIFO), drop its
        outstanding reservation, and clear the freed rows' positions on
        device so a future tenant never attends stale entries."""
        ids = self._slot_blocks[slot]
        self._slot_blocks[slot] = []
        self._slot_reserved[slot] = 0
        self.tables[slot, :] = -1
        self._dev_tables = None
        if ids:
            padded = np.zeros((self.max_blocks_per_slot,), np.int32)
            padded[:len(ids)] = ids
            self.state = tfm.PagedState(
                k=self.state.k, v=self.state.v,
                pos=_clear_pos(self.state.pos, jnp.asarray(padded)))
            self._free.extend(ids)
        return ids

    # -- device transfer ----------------------------------------------------

    def device_tables(self) -> jax.Array:
        if self._dev_tables is None:
            self._dev_tables = jnp.asarray(self.tables)
        return self._dev_tables

    def write_prefill(self, slot: int, dense_state, pads: int = 0) -> None:
        """Copy a dense prefill cache (``models.transformer.State`` for a
        B=1 request, budget == whole blocks) into the slot's blocks.
        ``pads`` left-pad rows get their positions forced to -1."""
        ids = self._slot_blocks[slot]
        bucket = dense_state.k.shape[2]
        if bucket != len(ids) * self.block_len:
            raise ValueError(f"bucket {bucket} != {len(ids)} blocks of "
                             f"{self.block_len}")
        pos = dense_state.kpos[0, 0]
        if pads:
            pos = jnp.where(jnp.arange(bucket) < pads, -1, pos)
        nb = len(ids)
        fn = self._write_fns.get(nb)
        if fn is None:
            fn = self._write_fns[nb] = jax.jit(scatter_prefill,
                                               donate_argnums=(0,))
        self.state = fn(self.state, dense_state.k, dense_state.v, pos,
                        jnp.asarray(ids, jnp.int32))
