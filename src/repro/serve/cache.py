"""Paged KV cache: one fixed block arena + per-slot block tables.

The arena carves ``n_blocks`` blocks of ``block_len`` token rows per layer
out of a single global token budget (``models.transformer.PagedState``), so
serving never re-allocates a cache per prompt-length bucket.  Each batch
slot owns an ordered *block table*; because a slot fills its blocks
strictly in order, the gathered table is a dense per-slot cache view in
which row ``p`` holds position ``p`` — ``attend_decode``'s ``pos == -1``
masking (the same path ragged cohort serving uses) does the rest.

This module is the HOST side: a free-list allocator with
``alloc / append / free`` lifecycle plus admission accounting.  A request
admitted with ``admit()`` reserves its full lifetime block count up front
(prefill blocks are allocated immediately, decode blocks lazily as the
sequence crosses block boundaries), so a mid-decode allocation can never
deadlock the arena: if the blocks aren't guaranteed, admission refuses.

Block id 0 is a scratch block: inactive slots' decode writes land there
and unused table entries gather it with positions forced to -1, so stale
rows are never attended.  Freed blocks get their position rows cleared on
``free_slot`` for the same reason.

Prefix sharing (``prefix_cache=True``) adds a content-address layer on
top: FULL blocks are registered under a chain hash of the token ids they
hold (hash of ``tokens[: (j+1)*block_len]``, so a match at block ``j``
implies all earlier blocks match too), and every block carries a
refcount.  ``admit_shared`` maps the longest registered prefix into a new
slot's table without copying — the slots literally share arena blocks.
``free_slot`` decrements refcounts; a registered block whose refcount
hits zero is *retained* in an evictable LRU pool (its content IS the
cache value) and only scrubbed when ``_alloc`` must evict it for fresh
storage.  A shared block is never mutated in place: ``append`` routes
through ``ensure_private`` which copy-on-writes the block when its
refcount is > 1 (the producer of that situation is ``fork_slot``;
scheduler-path sharing only ever maps full, finished blocks).
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..models import transformer as tfm

__all__ = ["PagedKVCache", "next_pow2", "scatter_prefill", "block_hashes"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


@partial(jax.jit, donate_argnums=(0,))
def _clear_pos(pos, ids):
    """Mark freed blocks' rows empty (ids padded with 0 = scratch block)."""
    return pos.at[ids].set(-1)


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _copy_block(k, v, pos, src, dst):
    """Copy-on-write: duplicate arena block ``src`` into ``dst`` (all
    layers + position rows).  Donated so the arena updates in place."""
    return (k.at[:, dst].set(k[:, src]),
            v.at[:, dst].set(v[:, src]),
            pos.at[dst].set(pos[src]))


def block_hashes(tokens: np.ndarray, n_blocks: int, block_len: int
                 ) -> List[bytes]:
    """Chain hashes for the first ``n_blocks`` FULL blocks of ``tokens``.

    Entry ``j`` digests ``tokens[: (j+1)*block_len]`` (incrementally), so
    equal hashes at ``j`` imply the whole prefix matches — a block is
    only ever shared together with everything before it."""
    toks = np.ascontiguousarray(tokens, dtype=np.int32)
    if len(toks) < n_blocks * block_len:
        raise ValueError(f"{n_blocks} blocks of {block_len} need "
                         f"{n_blocks * block_len} tokens, got {len(toks)}")
    h = hashlib.blake2b(digest_size=16)
    out: List[bytes] = []
    for j in range(n_blocks):
        h.update(toks[j * block_len:(j + 1) * block_len].tobytes())
        out.append(h.copy().digest())
    return out


def scatter_prefill(paged: tfm.PagedState, k_dense, v_dense, pos_dense, ids
                    ) -> tfm.PagedState:
    """Scatter one request's dense prefill cache into its arena blocks.

    Pure (traceable) so schedulers can fuse it with the prefill forward
    into one jitted dispatch.  k/v_dense: (L, 1, bucket, KV, hd);
    pos_dense: (bucket,) with pad rows already -1; ids: (nb,) target
    block ids, nb * block_len == bucket."""
    L, _, bucket, kv, hd = k_dense.shape
    nb = ids.shape[0]
    bl = bucket // nb
    k = k_dense[:, 0].reshape(L, nb, bl, kv, hd)
    v = v_dense[:, 0].reshape(L, nb, bl, kv, hd)
    pos = pos_dense.reshape(nb, bl)
    return tfm.PagedState(k=paged.k.at[:, ids].set(k),
                          v=paged.v.at[:, ids].set(v),
                          pos=paged.pos.at[ids].set(pos))


class PagedKVCache:
    """Block-arena KV cache for ``batch`` slots under one token budget.

    ``total_tokens`` is the global arena budget (rounded up to whole
    blocks); ``max_seq`` bounds any single slot's length and sizes the
    block table width.  The device arena lives in ``self.state``
    (a ``models.transformer.PagedState``)."""

    def __init__(self, cfg: ArchConfig, batch: int, *, total_tokens: int,
                 max_seq: int, block_len: int = 16, dtype=None,
                 prefix_cache: bool = False):
        if block_len < 1:
            raise ValueError("block_len must be >= 1")
        self.cfg = cfg
        self.batch = batch
        self.block_len = block_len
        self.prefix_cache = bool(prefix_cache)
        self.max_blocks_per_slot = max(
            1, math.ceil(max_seq / block_len))
        self.max_seq = self.max_blocks_per_slot * block_len
        # +1: block 0 is the reserved scratch block, never allocated
        self.n_blocks = 1 + max(self.blocks_for(total_tokens),
                                self.max_blocks_per_slot)
        self.state = tfm.init_paged_state(cfg, self.n_blocks, block_len,
                                          dtype=dtype)
        # LIFO free list: a just-freed block is re-used first
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self.tables = np.full((batch, self.max_blocks_per_slot), -1,
                              np.int32)
        self._slot_blocks: List[List[int]] = [[] for _ in range(batch)]
        # blocks promised to admitted slots but not yet allocated
        self._slot_reserved = np.zeros((batch,), np.int64)
        self._write_fns = {}            # n_prefill_blocks -> jitted scatter
        # device copy of self.tables, re-uploaded only when tables change
        # (most decode steps allocate nothing, so the upload is elided)
        self._dev_tables: Optional[jax.Array] = None
        # -- prefix sharing state (inert when prefix_cache is False) --------
        self._ref = np.zeros((self.n_blocks,), np.int32)
        self._block_hash: Dict[int, bytes] = {}     # block id -> chain hash
        self._hash_to_block: Dict[bytes, int] = {}  # chain hash -> block id
        # registered blocks with refcount 0, retained for future matches;
        # ordered oldest-freed first (eviction order)
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self.hit_tokens = 0   # prompt rows served from shared blocks
        self.miss_tokens = 0  # prompt rows computed fresh

    # -- accounting ---------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(max(int(n_tokens), 0) / self.block_len)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return int(self._slot_reserved.sum())

    @property
    def used_blocks(self) -> int:
        return sum(len(b) for b in self._slot_blocks)

    @property
    def evictable_blocks(self) -> int:
        """Registered refcount-0 blocks retained for prefix matches;
        reclaimable by ``_alloc`` at any time, so admission counts them
        as available."""
        return len(self._cached)

    @property
    def cache_hit_ratio(self) -> float:
        total = self.hit_tokens + self.miss_tokens
        return self.hit_tokens / total if total else 0.0

    def can_admit(self, lifetime_tokens: int) -> bool:
        """Fit-by-free-blocks admission: the request's whole lifetime
        (prefill + planned decode) must fit in unreserved free blocks.
        Conservative under prefix sharing: assumes a zero-length match
        (shared blocks only ever reduce the real draw), so an admitted
        request can never deadlock the arena."""
        need = self.blocks_for(lifetime_tokens)
        return (need <= self.free_blocks + self.evictable_blocks
                - self.reserved_blocks
                and need <= self.max_blocks_per_slot)

    # -- lifecycle ----------------------------------------------------------

    def _unregister(self, bid: int) -> None:
        h = self._block_hash.pop(bid, None)
        if h is not None and self._hash_to_block.get(h) == bid:
            del self._hash_to_block[h]

    def _scrub(self, ids: List[int]) -> None:
        """Clear freed blocks' position rows on device (in fixed-width
        groups so ``_clear_pos`` never recompiles)."""
        width = self.max_blocks_per_slot
        for i in range(0, len(ids), width):
            padded = np.zeros((width,), np.int32)
            group = ids[i:i + width]
            padded[:len(group)] = group
            self.state = tfm.PagedState(
                k=self.state.k, v=self.state.v,
                pos=_clear_pos(self.state.pos, jnp.asarray(padded)))

    def _alloc(self, n: int) -> List[int]:
        out: List[int] = []
        evicted: List[int] = []
        for _ in range(n):
            if self._free:
                out.append(self._free.pop())
            elif self._cached:
                # reclaim the least-recently-freed retained block: forget
                # its content address and scrub its rows before reuse
                bid, _ = self._cached.popitem(last=False)
                self._unregister(bid)
                evicted.append(bid)
                out.append(bid)
            else:
                if evicted:              # already unregistered: scrub them
                    self._scrub(evicted)
                self._free.extend(reversed(out))
                raise RuntimeError(
                    f"arena exhausted: need {n} blocks, {len(out)} "
                    f"available (admission accounting bug)")
        if evicted:
            self._scrub(evicted)
        for bid in out:
            self._ref[bid] = 1
        return out

    def admit(self, slot: int, prefill_tokens: int,
              lifetime_tokens: int) -> List[int]:
        """Reserve ``lifetime_tokens`` worth of blocks for ``slot`` and
        allocate the prefill prefix now.  Returns the prefill block ids."""
        if self._slot_blocks[slot] or self._slot_reserved[slot]:
            raise RuntimeError(f"slot {slot} already admitted")
        if not self.can_admit(lifetime_tokens):
            raise RuntimeError(f"slot {slot}: admission check not honored")
        n_now = self.blocks_for(prefill_tokens)
        total = max(self.blocks_for(lifetime_tokens), n_now)
        ids = self._alloc(n_now)
        self._slot_blocks[slot] = list(ids)
        self.tables[slot, :n_now] = ids
        self._dev_tables = None
        self._slot_reserved[slot] = total - n_now
        return ids

    def append(self, slot: int, pos: int) -> None:
        """Ensure the block holding row ``pos`` exists — and is safe to
        mutate — before a decode write.  Allocates the slot's next block
        (from its reservation) when ``pos`` crosses a block boundary;
        copy-on-writes the target when it is shared (refcount > 1)."""
        j = pos // self.block_len
        if j < len(self._slot_blocks[slot]):
            self.ensure_private(slot, j)
            return
        if j != len(self._slot_blocks[slot]) or j >= self.max_blocks_per_slot:
            raise RuntimeError(
                f"slot {slot}: non-contiguous append at pos {pos}")
        if self._slot_reserved[slot] <= 0:
            raise RuntimeError(
                f"slot {slot}: append beyond reserved lifetime at pos {pos}")
        (bid,) = self._alloc(1)
        self._slot_blocks[slot].append(bid)
        self.tables[slot, j] = bid
        self._dev_tables = None
        self._slot_reserved[slot] -= 1

    def ensure_private(self, slot: int, j: int) -> None:
        """Make the slot's ``j``-th block safe to mutate.

        refcount > 1: copy-on-write — allocate a fresh block (drawn from
        the slot's reservation, which ``fork_slot`` sized to include it),
        device-copy the shared content, and repoint this slot's table;
        the other holders keep the original.  refcount == 1 but still
        content-registered: unregister in place — the mutation is about
        to invalidate the hash (defensive: the chunked scheduler never
        mutates a registered block, see ``register_prefix``)."""
        bid = self._slot_blocks[slot][j]
        if self._ref[bid] > 1:
            if self._slot_reserved[slot] <= 0:
                raise RuntimeError(
                    f"slot {slot}: copy-on-write of block {bid} exceeds "
                    f"reserved lifetime")
            (new,) = self._alloc(1)
            self._slot_reserved[slot] -= 1
            k, v, pos = _copy_block(self.state.k, self.state.v,
                                    self.state.pos, bid, new)
            self.state = tfm.PagedState(k=k, v=v, pos=pos)
            self._slot_blocks[slot][j] = new
            self.tables[slot, j] = new
            self._dev_tables = None
            self._ref[bid] -= 1
        elif bid in self._block_hash:
            self._unregister(bid)

    def extend_to(self, slot: int, n_rows: int) -> None:
        """Chunked prefill: allocate blocks (from the reservation) so the
        slot's table covers rows ``[0, n_rows)``.  Shared prefix blocks
        mapped by ``admit_shared`` already count as covered."""
        need = self.blocks_for(n_rows)
        if need > self.max_blocks_per_slot:
            raise RuntimeError(
                f"slot {slot}: {n_rows} rows exceed max_seq {self.max_seq}")
        blocks = self._slot_blocks[slot]
        while len(blocks) < need:
            if self._slot_reserved[slot] <= 0:
                raise RuntimeError(
                    f"slot {slot}: extend beyond reserved lifetime at "
                    f"{n_rows} rows")
            (bid,) = self._alloc(1)
            self.tables[slot, len(blocks)] = bid
            blocks.append(bid)
            self._dev_tables = None
            self._slot_reserved[slot] -= 1

    def free_slot(self, slot: int) -> List[int]:
        """Release the slot: drop its outstanding lifetime reservation
        (even mid-prefill — reserved-but-unallocated blocks return to the
        admission pool), decrement each mapped block's refcount, and
        retire refcount-0 blocks.  Private retirees go back to the free
        list (LIFO) with their position rows scrubbed so a future tenant
        never attends stale entries; content-registered retirees are
        retained in the evictable prefix pool instead (their rows ARE the
        cached value — ``_alloc`` scrubs them only on eviction)."""
        ids = self._slot_blocks[slot]
        self._slot_blocks[slot] = []
        self._slot_reserved[slot] = 0
        self.tables[slot, :] = -1
        self._dev_tables = None
        to_free: List[int] = []
        for bid in ids:
            self._ref[bid] -= 1
            if self._ref[bid] > 0:
                continue                 # another slot still maps it
            if self.prefix_cache and bid in self._block_hash:
                self._cached[bid] = None
                self._cached.move_to_end(bid)
            else:
                self._unregister(bid)
                to_free.append(bid)
        if to_free:
            self._scrub(to_free)
            self._free.extend(to_free)
        return ids

    # -- prefix sharing -----------------------------------------------------

    def match_prefix(self, tokens: np.ndarray, max_rows: int) -> List[int]:
        """Longest registered prefix of ``tokens`` in whole blocks, capped
        at ``max_rows`` rows (callers cap to keep prefill dispatch shapes
        identical across hit lengths).  Returns the matching block ids in
        order; does NOT take references — ``admit_shared`` does."""
        if not self.prefix_cache:
            return []
        limit = min(len(tokens), max_rows) // self.block_len
        ids: List[int] = []
        for j, h in enumerate(block_hashes(tokens[:limit * self.block_len],
                                           limit, self.block_len)):
            bid = self._hash_to_block.get(h)
            if bid is None:
                break
            ids.append(bid)
        return ids

    def admit_shared(self, slot: int, tokens: np.ndarray,
                     lifetime_tokens: int, *, max_match_rows: int,
                     granule_rows: int = 0) -> int:
        """Admit ``slot`` for chunked prefill with prefix sharing.

        Maps the longest registered prefix of ``tokens`` (≤
        ``max_match_rows`` rows, whole blocks, rounded down to a multiple
        of ``granule_rows`` when given — the scheduler passes its chunk
        size so prefill resumes on an absolute chunk boundary) into the
        slot's table by reference — no copy — and reserves the rest of
        the lifetime for lazy allocation by ``extend_to``/``append``.
        Returns the number of prompt rows served from shared blocks (the
        scheduler starts prefill at that row).  Requires a prior
        ``can_admit`` check, which deliberately assumes a zero-length
        match."""
        if self._slot_blocks[slot] or self._slot_reserved[slot]:
            raise RuntimeError(f"slot {slot} already admitted")
        if not self.can_admit(lifetime_tokens):
            raise RuntimeError(f"slot {slot}: admission check not honored")
        plen = len(tokens)
        shared = self.match_prefix(tokens, max_match_rows)
        if granule_rows:
            if granule_rows % self.block_len:
                raise ValueError(
                    f"granule_rows {granule_rows} must be a multiple of "
                    f"block_len {self.block_len}")
            keep = (len(shared) * self.block_len
                    // granule_rows) * granule_rows // self.block_len
            shared = shared[:keep]
        for bid in shared:
            if self._ref[bid] == 0:
                self._cached.pop(bid, None)
            self._ref[bid] += 1
        m = len(shared)
        self._slot_blocks[slot] = list(shared)
        if m:
            self.tables[slot, :m] = shared
            self._dev_tables = None
        total = max(self.blocks_for(lifetime_tokens), self.blocks_for(plen))
        self._slot_reserved[slot] = total - m
        matched_rows = m * self.block_len
        self.hit_tokens += matched_rows
        self.miss_tokens += plen - matched_rows
        return matched_rows

    def extend_match(self, slot: int, tokens: np.ndarray, *,
                     max_match_rows: int, granule_rows: int = 0) -> int:
        """Re-match a slot admitted before its prefix producer finished.

        Only valid while the slot has written NOTHING (no chunk
        dispatched): its blocks are then exactly the shared prefix mapped
        at admission, and any blocks registered since (e.g. by a producer
        mid-prefill) can be grafted on by reference.  Returns the new
        total matched row count.  The extension draws on the slot's
        existing reservation, which admission sized for a zero-length
        match — so it can only shrink the eventual allocation."""
        blocks = self._slot_blocks[slot]
        m = len(blocks)
        full = self.match_prefix(tokens, max_match_rows)
        if granule_rows:
            keep = (len(full) * self.block_len
                    // granule_rows) * granule_rows // self.block_len
            full = full[:keep]
        if len(full) <= m or full[:m] != blocks:
            return m * self.block_len
        extra = full[m:]
        for bid in extra:
            if self._ref[bid] == 0:
                self._cached.pop(bid, None)
            self._ref[bid] += 1
        self.tables[slot, m:len(full)] = extra
        self._dev_tables = None
        blocks.extend(extra)
        self._slot_reserved[slot] -= len(extra)
        gained = len(extra) * self.block_len
        self.hit_tokens += gained
        self.miss_tokens -= gained
        return len(full) * self.block_len

    def register_prefix(self, slot: int, tokens: np.ndarray,
                        upto_rows: int) -> int:
        """Content-register the slot's blocks fully inside rows
        ``[0, upto_rows)`` so later admissions can share them.

        Callers only pass rows whose values are final (the chunked
        scheduler registers after the chunk dispatch that wrote them, and
        never a block that prefill or decode will write again — so a
        registered block's content can't drift from its hash).  Returns
        the number of newly registered blocks."""
        if not self.prefix_cache:
            return 0
        nb = min(upto_rows // self.block_len,
                 len(self._slot_blocks[slot]))
        added = 0
        hashes = block_hashes(tokens[:nb * self.block_len], nb,
                              self.block_len)
        for j, h in enumerate(hashes):
            bid = self._slot_blocks[slot][j]
            if bid in self._block_hash:
                continue                 # already registered (e.g. shared)
            if h in self._hash_to_block:
                continue                 # another block is canonical
            self._block_hash[bid] = h
            self._hash_to_block[h] = bid
            added += 1
        return added

    def fork_slot(self, src: int, dst: int, src_len: int,
                  lifetime_tokens: int) -> None:
        """Map ALL of ``src``'s blocks (including a partial last block)
        into ``dst`` by reference — the parallel-sampling hook.  Reserves
        ``dst``'s remaining lifetime plus one extra block iff the last
        shared block is partial: ``dst``'s first append into it triggers
        the copy-on-write in ``ensure_private``, which draws from that
        reservation."""
        if self._slot_blocks[dst] or self._slot_reserved[dst]:
            raise RuntimeError(f"slot {dst} already admitted")
        src_blocks = self._slot_blocks[src]
        if self.blocks_for(src_len) != len(src_blocks):
            raise ValueError(
                f"src_len {src_len} does not cover slot {src}'s "
                f"{len(src_blocks)} blocks")
        cow_extra = 1 if src_len % self.block_len else 0
        total = max(self.blocks_for(lifetime_tokens), len(src_blocks))
        need = total - len(src_blocks) + cow_extra
        if need > (self.free_blocks + self.evictable_blocks
                   - self.reserved_blocks):
            raise RuntimeError(f"fork into slot {dst}: arena cannot "
                               f"guarantee {need} blocks")
        if total > self.max_blocks_per_slot:
            raise RuntimeError(f"fork into slot {dst}: lifetime exceeds "
                               f"max_seq {self.max_seq}")
        for bid in src_blocks:
            self._ref[bid] += 1
        self._slot_blocks[dst] = list(src_blocks)
        self.tables[dst, :len(src_blocks)] = src_blocks
        self._dev_tables = None
        self._slot_reserved[dst] = need

    def reset_prefix_cache(self) -> None:
        """Forget all content registrations, reclaim the evictable pool,
        and zero the hit/miss counters — benches call this between warmup
        and measured replays so hit ratios reflect a cold start."""
        retained = list(self._cached)
        self._cached.clear()
        self._block_hash.clear()
        self._hash_to_block.clear()
        if retained:
            self._scrub(retained)
            self._free.extend(retained)
        self.hit_tokens = 0
        self.miss_tokens = 0

    # -- device transfer ----------------------------------------------------

    def device_tables(self) -> jax.Array:
        if self._dev_tables is None:
            self._dev_tables = jnp.asarray(self.tables)
        return self._dev_tables

    def write_prefill(self, slot: int, dense_state, pads: int = 0) -> None:
        """Copy a dense prefill cache (``models.transformer.State`` for a
        B=1 request, budget == whole blocks) into the slot's blocks.
        ``pads`` left-pad rows get their positions forced to -1."""
        ids = self._slot_blocks[slot]
        bucket = dense_state.k.shape[2]
        if bucket != len(ids) * self.block_len:
            raise ValueError(f"bucket {bucket} != {len(ids)} blocks of "
                             f"{self.block_len}")
        pos = dense_state.kpos[0, 0]
        if pads:
            pos = jnp.where(jnp.arange(bucket) < pads, -1, pos)
        nb = len(ids)
        fn = self._write_fns.get(nb)
        if fn is None:
            fn = self._write_fns[nb] = jax.jit(scatter_prefill,
                                               donate_argnums=(0,))
        self.state = fn(self.state, dense_state.k, dense_state.v, pos,
                        jnp.asarray(ids, jnp.int32))
