"""Slot-level continuous batching and the legacy static-cohort scheduler.

``ContinuousScheduler`` keeps a fixed number of batch *slots* decoding in
one jitted step over a shared :class:`~repro.serve.cache.PagedKVCache`
arena.  A finished slot is freed and refilled from the arrival queue on
the very next step, so short requests never hold the batch hostage the
way cohort scheduling does — occupancy stays near 1 under mixed-length
traffic, which is where the tokens/s win comes from.

Prefill runs one request at a time at ``B=1`` with the request's *exact*
token length (no left padding), then scatters the dense cache into the
slot's arena blocks.  Exact-length prefill makes every request's greedy
output bit-identical to a one-request-at-a-time oracle regardless of
arrival order, batch size, or what else shares the batch — the property
the serving tests pin.  The KV budget is bucketed to the next power of
two (whole blocks), so the *decode* step compiles exactly once.

``CohortScheduler`` is the old ``ServingLoop`` body behind the same
interface: take up to ``batch`` arrived requests, left-pad, prefill,
decode the cohort in lockstep until all members finish, repeat.  It
exists as the measured baseline the ``serve/*`` bench scenarios compare
against, with two fixes over the original: the prefill sample no longer
reuses the loop's PRNG key, and the prefill KV budget is bucketed to the
next power of two to cap jit recompiles across cohorts.

Time is *virtual*: arrivals are expressed in scheduler steps (one prefill
or one batch-decode step advances the clock by 1), so a trace replays
identically on any host speed.  Wall-clock is only used for the latency
metrics themselves (TTFT, decode ms).

Both schedulers report the same ``repro.obs.metrics`` names the original
loop did:

  serve.ttft_ms           histogram, per request (arrival -> first token)
  serve.decode_ms         histogram, per decode step (per-token latency)
  serve.batch_occupancy   histogram, active/batch per decode step
                          (per cohort prefill for CohortScheduler)
  serve.queue_depth       gauge, arrived requests not yet in a slot
  serve.requests_total    counter
  serve.tokens_total      counter
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..distributed import sharding as shd
from ..models import build_model
from ..obs import metrics as obs_metrics
from ..obs.trace import get_tracer
from .cache import PagedKVCache, next_pow2, scatter_prefill

__all__ = ["Request", "sample", "pack_prompts", "mask_padded_cache",
           "build_serve_fns", "ContinuousScheduler", "CohortScheduler"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0                # virtual-step arrival time
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    # filled in by the scheduler ----------------------------------------------
    ttft_ms: Optional[float] = None     # arrival -> first token (incl.
    #                                     queue wait)
    total_ms: Optional[float] = None    # arrival -> request finished


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def pack_prompts(active: List[Request], batch: int):
    """LEFT-pad ragged prompts into one (batch, max_len) int32 array.
    Returns (tokens, pads) where ``pads[i]`` is request i's pad count."""
    max_len = max(len(r.prompt) for r in active)
    tokens = np.zeros((batch, max_len), np.int32)
    pads = np.zeros((batch,), np.int32)
    for i, r in enumerate(active):
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        pads[i] = max_len - len(p)
        tokens[i, pads[i]:] = p
    return tokens, pads


def mask_padded_cache(state, pads: np.ndarray):
    """Rewrite the pad slots' cached positions to -1 so ``attend_decode``
    (which masks ``pos_cache < 0`` as empty) never attends them."""
    kpos = getattr(state, "kpos", None)
    if kpos is None or not np.any(pads):
        return state
    slot = jnp.arange(kpos.shape[-1], dtype=jnp.int32)
    pad_col = jnp.asarray(pads, jnp.int32)[None, :, None]
    masked = jnp.where(slot[None, None, :] < pad_col, -1, kpos)
    return state._replace(kpos=masked)


def build_serve_fns(model, rules=None, budget=None):
    def prefill(params, batch):
        with shd.use_rules(rules):
            return model.prefill(params, batch, budget=budget)

    def decode_step(params, state, tokens):
        with shd.use_rules(rules):
            return model.decode_step(params, state, tokens)

    return jax.jit(prefill), jax.jit(decode_step, donate_argnums=(1,))


def _request_key(base_key, uid: int):
    """Per-request PRNG stream: independent of scheduling order, so
    sampled outputs don't change when the batch composition does."""
    return jax.random.fold_in(base_key, uid)


class _SchedulerBase:
    """Shared construction + metrics wiring for both schedulers."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int,
                 rules=None, seed: int = 0, max_new: int = 64,
                 metrics: Optional[obs_metrics.Registry] = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.model = build_model(cfg)
        self.max_new = max_new
        self.rules = rules
        self.seed = seed
        self.key = jax.random.PRNGKey(seed)
        self.metrics = metrics if metrics is not None \
            else obs_metrics.Registry()

    def _metric_handles(self):
        m = self.metrics
        return (m.histogram("serve.ttft_ms"), m.histogram("serve.decode_ms"),
                m.histogram("serve.batch_occupancy"),
                m.gauge("serve.queue_depth"),
                m.counter("serve.requests_total"),
                m.counter("serve.tokens_total"))


class _Slot:
    """One occupied batch slot of the continuous scheduler."""

    __slots__ = ("req", "pos", "target", "t_arrive", "plen", "filled",
                 "prefilling", "started")

    def __init__(self, req: Request, pos: int, target: int, t_arrive: float,
                 plen: int = 0, filled: int = 0, prefilling: bool = False):
        self.req = req
        self.pos = pos          # next cache row this slot writes
        self.target = target    # tokens to emit (min(max_new, max_steps))
        self.t_arrive = t_arrive
        # chunked-prefill progress (unused by the monolithic path)
        self.plen = plen        # prompt rows this slot must prefill
        self.filled = filled    # prompt rows written so far (chunk-aligned)
        self.prefilling = prefilling
        self.started = False    # True once the first chunk dispatched


class ContinuousScheduler(_SchedulerBase):
    """Slot-level continuous batching over a paged KV arena.

    ``total_tokens`` sets the arena budget (default: enough for every
    slot to hold ``max_seq`` rows); ``max_seq`` bounds one request's
    prompt + generation; ``max_prefills_per_step`` caps how many arrivals
    are admitted between decode steps (default: the batch size).

    ``chunk_tokens`` switches prefill from one monolithic exact-length
    dispatch to fixed-size chunks interleaved with decode steps (one
    chunk, then one decode step, per scheduler step), bounding how long
    a queued long prompt can stall decoders.  Chunk boundaries are
    *absolute* row multiples of ``chunk_tokens`` and every chunk runs the
    same full-softmax dispatch shape, so greedy outputs stay bit-identical
    to a chunked solo oracle regardless of arrival order, batch mix, or
    prefix sharing (they are NOT bit-comparable to the monolithic path,
    whose online-softmax decomposition differs in low bits).
    ``prefix_cache`` additionally content-addresses finished full blocks
    and admits new prompts by mapping their longest cached prefix —
    implies chunked prefill (default ``block_len`` — the finest legal
    chunk, so as much of a shared prefix as possible lands on a match
    boundary) because shared rows must end on an absolute chunk
    boundary."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int,
                 rules=None, seed: int = 0, max_new: int = 64,
                 metrics: Optional[obs_metrics.Registry] = None,
                 block_len: int = 16, max_seq: int = 1024,
                 total_tokens: Optional[int] = None,
                 max_prefills_per_step: Optional[int] = None,
                 chunk_tokens: Optional[int] = None,
                 prefix_cache: bool = False):
        super().__init__(cfg, params, batch=batch, rules=rules, seed=seed,
                         max_new=max_new, metrics=metrics)
        if self.model.decode_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged decode path; use "
                "CohortScheduler")
        self.block_len = block_len
        self.max_seq = max_seq
        if prefix_cache and chunk_tokens is None:
            # finest legal chunk: match length is capped to chunk
            # multiples, so coarser defaults silently shrink sharing
            chunk_tokens = block_len
        if chunk_tokens is not None:
            if chunk_tokens < block_len or chunk_tokens % block_len:
                raise ValueError(
                    f"chunk_tokens {chunk_tokens} must be a positive "
                    f"multiple of block_len {block_len}")
            if int(cfg.n_patches or 0) > 0:
                raise ValueError(
                    "chunked prefill does not support vlm prompts (patch "
                    "rows cannot be chunk-aligned); use the monolithic "
                    "path")
        self.chunk_tokens = chunk_tokens
        self.prefix_cache = bool(prefix_cache)
        if total_tokens is None:
            total_tokens = batch * max_seq
        self.cache = PagedKVCache(cfg, batch, total_tokens=total_tokens,
                                  max_seq=max_seq, block_len=block_len,
                                  prefix_cache=self.prefix_cache)
        self.max_prefills_per_step = (batch if max_prefills_per_step is None
                                      else max_prefills_per_step)
        self._prefill_fns = {}          # KV bucket -> jitted prefill
        self._chunk_fns = {}            # pow2 chunk width -> jitted chunk
        # vlm prompts prepend n_patches rows to the cache during prefill
        self._extra_rows = int(cfg.n_patches or 0)

        model, rules_ = self.model, self.rules

        def _decode(params, paged, tokens, tables, slot_pos):
            with shd.use_rules(rules_):
                logits, paged = model.decode_paged(params, paged, tokens,
                                                   tables, slot_pos)
            # fold the greedy pick into the same dispatch: one jit call
            # per decode step instead of decode + eager argmax
            return logits, jnp.argmax(logits, axis=-1), paged

        self._decode = jax.jit(_decode, donate_argnums=(1,))

    # -- helpers ------------------------------------------------------------

    def _bucket(self, prompt_len: int) -> int:
        """KV budget for one prefill: next power of two, whole blocks."""
        b = max(next_pow2(max(prompt_len, 1)), self.block_len)
        bl = self.block_len
        return -(-b // bl) * bl

    def _get_prefill(self, bucket: int):
        """Fused prefill -> scatter-into-blocks -> greedy pick, one jitted
        dispatch per admission (donating the arena)."""
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            model, rules = self.model, self.rules

            def prefill_write(params, batch, paged, ids):
                with shd.use_rules(rules):
                    logits, dense = model.prefill(params, batch,
                                                  budget=bucket)
                paged = scatter_prefill(paged, dense.k, dense.v,
                                        dense.kpos[0, 0], ids)
                return logits, jnp.argmax(logits, axis=-1), paged

            fn = self._prefill_fns[bucket] = jax.jit(
                prefill_write, donate_argnums=(2,))
        return fn

    def _get_chunk(self, width: int):
        """Jitted single-slot prefill chunk at pow2 ``width`` (compiles
        once per width: at most log2(next_pow2(chunk_tokens)) + 1 entries
        across any trace — the jit-cache-boundedness tests pin this)."""
        fn = self._chunk_fns.get(width)
        if fn is None:
            model, rules = self.model, self.rules

            def chunk_step(params, paged, tokens, table, start, n_real):
                with shd.use_rules(rules):
                    logits, paged = model.prefill_chunk(
                        params, paged, tokens, table, start, n_real)
                return logits, jnp.argmax(logits, axis=-1), paged

            fn = self._chunk_fns[width] = jax.jit(chunk_step,
                                                  donate_argnums=(1,))
        return fn

    def _prefill_batch(self, prompt: np.ndarray):
        batch = {"tokens": jnp.asarray(
            np.asarray(prompt, np.int32).reshape(1, -1))}
        if self.cfg.n_patches:
            batch["patches"] = jnp.zeros(
                (1, self.cfg.n_patches, self.cfg.d_model), jnp.float32)
        return batch

    # -- main loop ----------------------------------------------------------

    def run(self, requests: List[Request], temperature: float = 0.0,
            max_steps: int = 64) -> Dict[int, List[int]]:
        if self.chunk_tokens is not None:
            return self._run_chunked(requests, temperature, max_steps)
        tracer = get_tracer()
        ttft_h, dec_h, occ_h, qdepth, req_c, tok_c = self._metric_handles()
        base_key = jax.random.PRNGKey(self.seed)

        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.uid)))
        queue: deque = deque()          # arrived, waiting for a slot
        arrive_wall: Dict[int, float] = {}
        slots: List[Optional[_Slot]] = [None] * self.batch
        results: Dict[int, List[int]] = {}
        clock = 0.0                     # virtual steps

        def finish(i: int):
            s = slots[i]
            s.req.done = True
            s.req.total_ms = (time.perf_counter() - s.t_arrive) * 1e3
            results[s.req.uid] = s.req.out_tokens
            req_c.inc()
            tok_c.inc(len(s.req.out_tokens))
            self.cache.free_slot(i)
            slots[i] = None

        while pending or queue or any(s is not None for s in slots):
            # arrivals: pending -> queue once the virtual clock reaches them
            now = time.perf_counter()
            while pending and pending[0].arrival <= clock:
                r = pending.popleft()
                queue.append(r)
                arrive_wall[r.uid] = now
            qdepth.set(len(queue))

            # admission: refill free slots while the arena has room
            n_pref = 0
            while queue and n_pref < self.max_prefills_per_step:
                free = [i for i, s in enumerate(slots) if s is None]
                if not free:
                    break
                r = queue[0]
                target = min(r.max_new, max_steps)
                plen = len(r.prompt) + self._extra_rows
                bucket = self._bucket(plen)
                lifetime = max(bucket, plen + target)
                if not self.cache.can_admit(lifetime):
                    if not any(s is not None for s in slots):
                        raise RuntimeError(
                            f"request {r.uid} (lifetime {lifetime} tokens) "
                            f"cannot fit the arena even when idle")
                    break               # wait for a slot to free blocks
                queue.popleft()
                i = free[0]
                with tracer.span("serve.prefill", uid=r.uid,
                                 prompt_len=len(r.prompt), bucket=bucket):
                    ids = self.cache.admit(i, bucket, lifetime)
                    logits, greedy, self.cache.state = self._get_prefill(
                        bucket)(self.params, self._prefill_batch(r.prompt),
                                self.cache.state,
                                jnp.asarray(ids, jnp.int32))
                    if temperature <= 0:
                        tok = int(jax.block_until_ready(greedy)[0])
                    else:
                        key = _request_key(base_key, r.uid)
                        tok = int(jax.block_until_ready(
                            sample(logits, jax.random.fold_in(key, 0),
                                   temperature))[0])
                t_first = time.perf_counter()
                r.ttft_ms = (t_first - arrive_wall[r.uid]) * 1e3
                ttft_h.observe(r.ttft_ms)
                r.out_tokens.append(tok)
                slots[i] = _Slot(r, pos=plen, target=target,
                                 t_arrive=arrive_wall[r.uid])
                if len(r.out_tokens) >= target:
                    finish(i)
                n_pref += 1
                clock += 1.0
                now = time.perf_counter()
                while pending and pending[0].arrival <= clock:
                    rr = pending.popleft()
                    queue.append(rr)
                    arrive_wall[rr.uid] = now
                qdepth.set(len(queue))

            active = [i for i, s in enumerate(slots) if s is not None]
            if not active:
                if pending:
                    # idle: jump the virtual clock to the next arrival
                    clock = max(clock, pending[0].arrival)
                    continue
                if queue:
                    continue            # admission will retry (or raise)
                break

            # one decode step over every slot (inactive slots write the
            # scratch block and their logits are discarded)
            occ_h.observe(len(active) / self.batch)
            tokens = np.zeros((self.batch, 1), np.int32)
            slot_pos = np.zeros((self.batch,), np.int32)
            for i in active:
                s = slots[i]
                tokens[i, 0] = s.req.out_tokens[-1]
                slot_pos[i] = s.pos
                self.cache.append(i, s.pos)
            t0 = time.perf_counter()
            with tracer.span("serve.decode_step", n_active=len(active),
                             queued=len(queue)):
                logits, greedy, self.cache.state = self._decode(
                    self.params, self.cache.state, jnp.asarray(tokens),
                    self.cache.device_tables(), jnp.asarray(slot_pos))
                if temperature <= 0:
                    toks = jax.block_until_ready(greedy)
                else:
                    toks = np.zeros((self.batch,), np.int64)
                    for i in active:
                        s = slots[i]
                        key = _request_key(base_key, s.req.uid)
                        step_key = jax.random.fold_in(
                            key, len(s.req.out_tokens))
                        toks[i] = int(jax.block_until_ready(sample(
                            logits[i:i + 1], step_key, temperature))[0])
            dec_h.observe((time.perf_counter() - t0) * 1e3)
            clock += 1.0
            for i in active:
                s = slots[i]
                s.pos += 1
                s.req.out_tokens.append(int(toks[i]))
                if len(s.req.out_tokens) >= s.target:
                    finish(i)
        qdepth.set(0)
        return results

    def _run_chunked(self, requests: List[Request], temperature: float,
                     max_steps: int) -> Dict[int, List[int]]:
        """Chunked-prefill loop: admission only reserves arena blocks
        (and maps any shared prefix); each scheduler step then dispatches
        one prefill chunk for the oldest mid-prefill slot, followed by
        one decode step over the fully-prefilled slots.  Mid-prefill
        slots are masked out of the decode dispatch's block table so the
        inactive-row scratch write can never land in their (possibly
        shared) blocks."""
        tracer = get_tracer()
        ttft_h, dec_h, occ_h, qdepth, req_c, tok_c = self._metric_handles()
        hit_c = self.metrics.counter("serve.prefix_hit_tokens")
        miss_c = self.metrics.counter("serve.prefix_miss_tokens")
        base_key = jax.random.PRNGKey(self.seed)
        T = self.chunk_tokens

        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.uid)))
        queue: deque = deque()          # arrived, waiting for a slot
        arrive_wall: Dict[int, float] = {}
        slots: List[Optional[_Slot]] = [None] * self.batch
        results: Dict[int, List[int]] = {}
        clock = 0.0                     # virtual steps

        def finish(i: int):
            s = slots[i]
            s.req.done = True
            s.req.total_ms = (time.perf_counter() - s.t_arrive) * 1e3
            results[s.req.uid] = s.req.out_tokens
            req_c.inc()
            tok_c.inc(len(s.req.out_tokens))
            self.cache.free_slot(i)
            slots[i] = None

        def drain_arrivals():
            now = time.perf_counter()
            while pending and pending[0].arrival <= clock:
                r = pending.popleft()
                queue.append(r)
                arrive_wall[r.uid] = now
            qdepth.set(len(queue))

        while pending or queue or any(s is not None for s in slots):
            drain_arrivals()

            # admission: reserve blocks + map shared prefix, no dispatch.
            # The match is capped to whole chunks strictly below the
            # prompt's last row, so at least one chunk (and the first
            # token's logits) is always computed live with the same
            # dispatch shape the solo oracle uses.
            n_adm = 0
            while queue and n_adm < self.max_prefills_per_step:
                free = [i for i, s in enumerate(slots) if s is None]
                if not free:
                    break
                r = queue[0]
                target = min(r.max_new, max_steps)
                plen = len(r.prompt)
                lifetime = plen + target
                if not self.cache.can_admit(lifetime):
                    if not any(s is not None for s in slots):
                        raise RuntimeError(
                            f"request {r.uid} (lifetime {lifetime} tokens)"
                            f" cannot fit the arena even when idle")
                    break               # wait for a slot to free blocks
                queue.popleft()
                i = free[0]
                matched = self.cache.admit_shared(
                    i, np.asarray(r.prompt, np.int32).reshape(-1),
                    lifetime, max_match_rows=((plen - 1) // T) * T,
                    granule_rows=T)
                hit_c.inc(matched)
                miss_c.inc(plen - matched)
                slots[i] = _Slot(r, pos=plen, target=target,
                                 t_arrive=arrive_wall[r.uid], plen=plen,
                                 filled=matched, prefilling=True)
                n_adm += 1

            # one prefill chunk for the oldest mid-prefill slot
            pref = [i for i, s in enumerate(slots)
                    if s is not None and s.prefilling]
            if pref:
                i = min(pref, key=lambda j: (slots[j].req.arrival,
                                             slots[j].req.uid))
                s = slots[i]
                r = s.req
                if not s.started:
                    # last chance to share: a producer that was still
                    # mid-prefill at our admission has registered its
                    # completed chunks by now — graft them on while this
                    # slot has written nothing
                    grown = self.cache.extend_match(
                        i, np.asarray(r.prompt, np.int32).reshape(-1),
                        max_match_rows=((s.plen - 1) // T) * T,
                        granule_rows=T)
                    if grown > s.filled:
                        hit_c.inc(grown - s.filled)
                        miss_c.inc(s.filled - grown)
                        s.filled = grown
                    s.started = True
                start = s.filled
                n = min(T, s.plen - start)
                width = next_pow2(n)
                self.cache.extend_to(i, start + n)
                toks = np.zeros((1, width), np.int32)
                toks[0, :n] = np.asarray(r.prompt,
                                         np.int32).reshape(-1)[start:start + n]
                with tracer.span("serve.prefill_chunk", uid=r.uid,
                                 start=start, n_tokens=n):
                    logits, greedy, self.cache.state = self._get_chunk(
                        width)(self.params, self.cache.state,
                               jnp.asarray(toks),
                               jnp.asarray(self.cache.tables[i:i + 1]),
                               jnp.int32(start), jnp.int32(n))
                    s.filled = start + n
                    last = s.filled >= s.plen
                    if last:
                        if temperature <= 0:
                            tok = int(jax.block_until_ready(greedy)[0])
                        else:
                            key = _request_key(base_key, r.uid)
                            tok = int(jax.block_until_ready(
                                sample(logits, jax.random.fold_in(key, 0),
                                       temperature))[0])
                clock += 1.0
                # register incrementally: rows in completed absolute
                # chunks are final (later chunks never rewrite them), so
                # a prompt arriving mid-prefill can already share them.
                # Only FULL aligned chunks qualify — rows of a final
                # partial chunk ran at a different dispatch width, so
                # their low bits are not what a sharing consumer's
                # oracle would produce.
                self.cache.register_prefix(
                    i, np.asarray(r.prompt, np.int32).reshape(-1),
                    (s.filled // T) * T)
                if last:
                    r.ttft_ms = (time.perf_counter()
                                 - arrive_wall[r.uid]) * 1e3
                    ttft_h.observe(r.ttft_ms)
                    r.out_tokens.append(tok)
                    s.prefilling = False
                    if len(r.out_tokens) >= s.target:
                        finish(i)
                drain_arrivals()

            active = [i for i, s in enumerate(slots)
                      if s is not None and not s.prefilling]
            if not active:
                if any(s is not None for s in slots):
                    continue            # prefill chunks still in flight
                if pending:
                    # idle: jump the virtual clock to the next arrival
                    clock = max(clock, pending[0].arrival)
                    continue
                if queue:
                    continue            # admission will retry (or raise)
                break

            # one decode step over the fully-prefilled slots
            occ_h.observe(len(active) / self.batch)
            pref = [i for i, s in enumerate(slots)
                    if s is not None and s.prefilling]
            tokens = np.zeros((self.batch, 1), np.int32)
            slot_pos = np.zeros((self.batch,), np.int32)
            for i in active:
                s = slots[i]
                tokens[i, 0] = s.req.out_tokens[-1]
                slot_pos[i] = s.pos
                self.cache.append(i, s.pos)
            if pref:
                # mask mid-prefill slots: their decode rows are inactive
                # (slot_pos 0) and must write the scratch block, not the
                # real block their table maps at row 0
                tbl = self.cache.tables.copy()
                tbl[pref] = -1
                tables = jnp.asarray(tbl)
            else:
                tables = self.cache.device_tables()
            t0 = time.perf_counter()
            with tracer.span("serve.decode_step", n_active=len(active),
                             queued=len(queue), prefilling=len(pref)):
                logits, greedy, self.cache.state = self._decode(
                    self.params, self.cache.state, jnp.asarray(tokens),
                    tables, jnp.asarray(slot_pos))
                if temperature <= 0:
                    toks = jax.block_until_ready(greedy)
                else:
                    toks = np.zeros((self.batch,), np.int64)
                    for i in active:
                        s = slots[i]
                        key = _request_key(base_key, s.req.uid)
                        step_key = jax.random.fold_in(
                            key, len(s.req.out_tokens))
                        toks[i] = int(jax.block_until_ready(sample(
                            logits[i:i + 1], step_key, temperature))[0])
            dec_h.observe((time.perf_counter() - t0) * 1e3)
            clock += 1.0
            for i in active:
                s = slots[i]
                s.pos += 1
                s.req.out_tokens.append(int(toks[i]))
                if len(s.req.out_tokens) >= s.target:
                    finish(i)
        qdepth.set(0)
        return results


class CohortScheduler(_SchedulerBase):
    """Static-cohort serving: up to ``batch`` arrived requests prefill
    together, decode in lockstep until every member finishes, then the
    next cohort forms.  The measured baseline for continuous batching."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int,
                 rules=None, seed: int = 0, max_new: int = 64,
                 metrics: Optional[obs_metrics.Registry] = None):
        super().__init__(cfg, params, batch=batch, rules=rules, seed=seed,
                         max_new=max_new, metrics=metrics)
        self._fns = {}          # KV budget bucket -> (prefill, decode)

    def _get_fns(self, prompt_len: int):
        # power-of-two budget bucketing: cohorts whose budgets round to
        # the same bucket share one decode compilation instead of
        # recompiling per distinct (prompt_len + max_new)
        budget = next_pow2(prompt_len + self.max_new + 1)
        if budget not in self._fns:
            self._fns[budget] = build_serve_fns(self.model, self.rules,
                                                budget=budget)
        return self._fns[budget]

    def run(self, requests: List[Request], temperature: float = 0.0,
            max_steps: int = 64) -> Dict[int, List[int]]:
        tracer = get_tracer()
        ttft_h, dec_h, occ_h, qdepth, req_c, tok_c = self._metric_handles()

        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.uid)))
        queue: deque = deque()
        arrive_wall: Dict[int, float] = {}
        results: Dict[int, List[int]] = {}
        clock = 0.0

        while pending or queue:
            now = time.perf_counter()
            while pending and pending[0].arrival <= clock:
                r = pending.popleft()
                queue.append(r)
                arrive_wall[r.uid] = now
            if not queue:               # idle until the next arrival
                clock = max(clock, pending[0].arrival)
                continue
            active = [queue.popleft()
                      for _ in range(min(self.batch, len(queue)))]
            qdepth.set(len(queue))
            occ_h.observe(len(active) / self.batch)
            with tracer.span("serve.batch", n_active=len(active),
                             queued=len(queue)):
                prompts, pads = pack_prompts(active, self.batch)
                prefill_fn, decode_fn = self._get_fns(prompts.shape[1])
                batch = {"tokens": jnp.asarray(prompts)}
                if self.cfg.is_encdec:
                    batch["frames"] = jnp.zeros(
                        (self.batch, prompts.shape[1], self.cfg.d_model),
                        jnp.float32)
                if self.cfg.n_patches:
                    batch["patches"] = jnp.zeros(
                        (self.batch, self.cfg.n_patches, self.cfg.d_model),
                        jnp.float32)
                with tracer.span("serve.prefill",
                                 prompt_len=int(prompts.shape[1])):
                    logits, state = prefill_fn(self.params, batch)
                    state = mask_padded_cache(state, pads)
                    # split before sampling: the loop key must never be
                    # consumed directly, or the next split replays it
                    self.key, sub = jax.random.split(self.key)
                    toks = sample(logits, sub, temperature)[:, None]
                    toks = jax.block_until_ready(toks)
                clock += 1.0
                t_first = time.perf_counter()
                for r in active:
                    r.ttft_ms = (t_first - arrive_wall[r.uid]) * 1e3
                    ttft_h.observe(r.ttft_ms)
                for step in range(max_steps):
                    for i, r in enumerate(active):
                        if not r.done and len(r.out_tokens) < r.max_new:
                            r.out_tokens.append(int(toks[i, 0]))
                        elif not r.done:
                            r.done = True
                    if all(r.done or len(r.out_tokens) >= r.max_new
                           for r in active):
                        break
                    self.key, sub = jax.random.split(self.key)
                    t0 = time.perf_counter()
                    with tracer.span("serve.decode_step", step=step):
                        logits, state = decode_fn(self.params, state,
                                                  toks.astype(jnp.int32))
                        toks = sample(logits, sub, temperature)[:, None]
                        toks = jax.block_until_ready(toks)
                    dec_h.observe((time.perf_counter() - t0) * 1e3)
                    clock += 1.0
                t_done = time.perf_counter()
                for r in active:
                    r.total_ms = (t_done - arrive_wall[r.uid]) * 1e3
                    results[r.uid] = r.out_tokens
                    req_c.inc()
                    tok_c.inc(len(r.out_tokens))
        qdepth.set(0)
        return results
