"""Deterministic arrival traces for the serving scenarios.

A trace is a list of :class:`~repro.serve.scheduler.Request` whose
``arrival`` times are expressed in *virtual scheduler steps* (one prefill
or one batch decode step = 1.0), so the same seed replays the identical
workload on any host speed — the property the ``serve/*`` bench rows and
their CI gate depend on.

Three arrival processes, matching the serving literature's standard trio:

  uniform  requests evenly spaced at ``1 / rate`` steps
  poisson  exponential inter-arrival gaps at mean ``1 / rate``
  bursty   poisson gaps, but arrivals land in bursts of ``burst`` at the
           same instant (doubly-stochastic: stresses admission + queue)

Prompt lengths and per-request ``max_new`` are drawn from closed ranges
so traces exercise the ragged/mixed-length path; ``max_new`` variation is
the proxy for EOS-driven early exit (the smoke models never emit EOS).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .scheduler import Request

__all__ = ["make_trace", "ARRIVALS"]

ARRIVALS = ("uniform", "poisson", "bursty")


def _gaps(kind: str, n: int, rate: float, burst: int,
          rng: np.random.Generator) -> np.ndarray:
    # validate BEFORE the rate shortcut: an unknown kind (or a bad burst)
    # must fail loudly even when rate == 0 would make the gaps trivial
    if kind not in ARRIVALS:
        raise ValueError(f"unknown arrival kind {kind!r} (want one of "
                         f"{ARRIVALS})")
    if rate < 0:
        raise ValueError(f"rate must be >= 0, got {rate}")
    if kind == "bursty" and burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    if rate == 0:
        # rate 0 = everything arrives at t=0 (the all-at-once workload);
        # no RNG draw, so it is identical across seeds and arrival kinds
        return np.zeros(n)
    if kind == "uniform":
        return np.full(n, 1.0 / rate)
    if kind == "poisson":
        return rng.exponential(1.0 / rate, n)
    # bursty: burst heads draw an exponential gap scaled so the long-run
    # rate still matches; burst members arrive with the head.  burst == 1
    # degenerates to poisson (every request is a head, scale 1/rate).
    gaps = np.zeros(n)
    heads = np.arange(n) % burst == 0
    gaps[heads] = rng.exponential(burst / rate, int(heads.sum()))
    return gaps


def make_trace(kind: str, n_requests: int, *, vocab: int,
               rate: float = 1.0, burst: int = 4, seed: int = 0,
               prompt_lens: Tuple[int, int] = (5, 24),
               max_new: Tuple[int, int] = (8, 40),
               prefix_len: int = 0, prefix_group: int = 0,
               arrival_rng: Optional[np.random.Generator] = None
               ) -> List[Request]:
    """Build ``n_requests`` requests with ``kind`` arrivals at ``rate``
    requests per virtual step.  ``prompt_lens`` / ``max_new`` are closed
    [lo, hi] ranges sampled per request.

    ``prefix_len > 0`` makes this a *shared-prefix* trace: requests are
    grouped in runs of ``prefix_group`` (default: all of them) and every
    request in a group gets the same ``prefix_len`` leading tokens, with
    its own ``prompt_lens``-range tail appended — the workload prefix
    caching exists for (system prompts, few-shot preambles).  With
    ``prefix_len == 0`` (the default) the RNG draw sequence is exactly
    the historical one, so existing traces and baselines replay
    unchanged."""
    if n_requests < 0:
        raise ValueError(f"n_requests must be >= 0, got {n_requests}")
    if prefix_len < 0:
        raise ValueError(f"prefix_len must be >= 0, got {prefix_len}")
    rng = np.random.default_rng(seed)
    # draw request shapes and contents before the arrival gaps so the
    # same seed yields the same prompts under every arrival kind
    lens = rng.integers(prompt_lens[0], prompt_lens[1] + 1, n_requests)
    news = rng.integers(max_new[0], max_new[1] + 1, n_requests)
    prompts = [rng.integers(0, vocab, (int(n),)).astype(np.int32)
               for n in lens]
    if prefix_len > 0 and n_requests > 0:
        group = prefix_group if prefix_group > 0 else n_requests
        n_groups = -(-n_requests // group)
        prefixes = [rng.integers(0, vocab, (prefix_len,)).astype(np.int32)
                    for _ in range(n_groups)]
        prompts = [np.concatenate([prefixes[i // group], prompts[i]])
                   for i in range(n_requests)]
    gaps = _gaps(kind, n_requests, rate, burst, arrival_rng or rng)
    arrivals = np.cumsum(gaps)
    return [Request(uid=i, prompt=prompts[i], max_new=int(news[i]),
                    arrival=float(arrivals[i]))
            for i in range(n_requests)]
