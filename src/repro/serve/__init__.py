"""Continuous-batching serving subsystem: paged KV cache + schedulers.

See ``src/repro/serve/README.md`` for the architecture.  The launch-layer
entry point (CLI + ``ServingLoop`` wrapper) lives in
``repro.launch.serve``; the bench scenario family in
``repro.bench.serving``.
"""
from .cache import PagedKVCache, block_hashes, next_pow2
from .scheduler import (CohortScheduler, ContinuousScheduler, Request,
                        build_serve_fns, mask_padded_cache, pack_prompts,
                        sample)
from .traces import ARRIVALS, make_trace

__all__ = [
    "PagedKVCache", "block_hashes", "next_pow2",
    "CohortScheduler", "ContinuousScheduler", "Request",
    "build_serve_fns", "mask_padded_cache", "pack_prompts", "sample",
    "ARRIVALS", "make_trace",
]
