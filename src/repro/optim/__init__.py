from .adamw import (AdamWState, adamw_init, adamw_update, global_norm,
                    lr_schedule, moment_shardings, zero1_spec)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm",
           "lr_schedule", "moment_shardings", "zero1_spec"]
