"""AdamW with global-norm clipping and ZeRO-1 moment sharding.

The moments (m, v) dominate optimizer memory (2x params fp32).  With ZeRO-1
enabled they are additionally sharded over the data axes — the update is
elementwise, so any sharding of the moments is valid; XLA inserts the
(reduce-)scatter/gather around the update automatically.  For qwen3-moe-235b
this is the difference between 7.1 GB and 0.44 GB of moments per chip.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import ShardingRules


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jnp.zeros_like(t, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(t.astype(jnp.float32)))
                        for t in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """lr may be a scalar array (schedule evaluated outside)."""
    step = state.step + 1
    gn = global_norm(grads)
    if clip_norm > 0:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / c1
        vh = v2 / c2
        step_v = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_v).astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step, new_m, new_v), gn


# ---------------------------------------------------------------------------
# ZeRO-1 sharding for the moments
# ---------------------------------------------------------------------------

def zero1_spec(axes: Tuple, shape: Tuple[int, ...], rules: ShardingRules):
    """Insert the data axes into the first unsharded, divisible dim of the
    param's spec — the ZeRO-1 placement for its moments."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from ..distributed.sharding import safe_spec
    base = list(safe_spec(rules, axes, shape))
    data_axes = rules.rules.get("batch")
    if data_axes is None:
        return P(*base)
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    dsize = int(np.prod([rules.mesh.shape[a] for a in data_axes]))
    used = set()
    for spec in base:
        for a in (spec if isinstance(spec, tuple) else (spec,)):
            if a is not None:
                used.add(a)
    if not any(a in used for a in data_axes):
        for i, (spec, dim) in enumerate(zip(base, shape)):
            if spec is None and dim % dsize == 0 and dim > 0:
                base[i] = tuple(data_axes) if len(data_axes) > 1 \
                    else data_axes[0]
                break
    return P(*base)


def moment_shardings(axes_tree, shapes_tree, rules: ShardingRules):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda ax, shp: NamedSharding(
            rules.mesh, zero1_spec(ax, shp.shape, rules)),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def lr_schedule(step, *, lr: float, warmup: int, total: int,
                min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
