"""Three-term roofline analysis from compiled XLA artifacts.

For every (architecture x shape x mesh) dry-run cell we derive, per chip:

    compute term    = HLO_FLOPs / PEAK_FLOPS            [s]
    memory term     = HLO_bytes / HBM_BW                [s]
    collective term = wire_bytes_per_chip / ICI_BW      [s]

``compiled.cost_analysis()`` provides HLO_FLOPs / HLO_bytes for the per-device
SPMD program.  Collective traffic is NOT in cost_analysis, so we parse the HLO
text and, for each all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, estimate the per-chip wire bytes under ring algorithms:

    all-gather       shard * (N-1)            (each device forwards N-1 shards)
    reduce-scatter   input * (N-1)/N
    all-reduce       input * 2(N-1)/N         (RS + AG)
    all-to-all       input * (N-1)/N
    collective-permute  input * 1

where N is the replica-group size parsed from the op's ``replica_groups``.
Reported times are *per-chip* seconds, directly comparable across terms (the
prompt's ``collective_bytes / (chips x link_bw)`` with whole-job bytes equals
per-chip wire bytes / link_bw).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional

from . import hardware


DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(dtype: str, dims: str) -> int:
    nb = DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * nb


@dataclass
class CollectiveOp:
    kind: str
    bytes_in: int          # summed operand bytes (per device)
    bytes_out: int
    group_size: int
    wire_bytes: float      # per-chip ring-algorithm wire traffic


def _ring_wire_bytes(kind: str, bytes_in: int, bytes_out: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return float(bytes_in) * (n - 1)
    if kind == "reduce-scatter":
        return float(bytes_in) * (n - 1) / n
    if kind == "all-reduce":
        return float(bytes_in) * 2 * (n - 1) / n
    if kind == "all-to-all":
        return float(bytes_in) * (n - 1) / n
    if kind == "collective-permute":
        return float(bytes_in)
    return float(bytes_in)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Extract collective ops + ring wire-bytes estimates from HLO text."""
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        rhs = s.split(" = ", 1)[1]
        # opcode follows the output shape:  f32[8,16]{1,0} all-reduce(...)
        kind = None
        for k in _COLLECTIVE_KINDS:
            if re.search(rf"\b{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done" in rhs:
            continue  # count only the -start of async pairs
        shapes = _SHAPE_RE.findall(s)
        if not shapes:
            continue
        # first shape literal = output; shapes inside parens = operands.
        paren = s.find("(")
        out_shapes = _SHAPE_RE.findall(s[:paren]) if paren > 0 else shapes[:1]
        in_shapes = _SHAPE_RE.findall(s[paren:]) if paren > 0 else []
        bytes_out = sum(shape_bytes(d, dims) for d, dims in out_shapes)
        bytes_in = sum(shape_bytes(d, dims) for d, dims in in_shapes)
        m = _GROUPS_RE.search(s)
        if m:
            group = m.group(1)
            n = len([g for g in group.split(",") if g.strip() != ""])
        else:
            m2 = _GROUPS_IOTA_RE.search(s)
            n = int(m2.group(2)) if m2 else 1
        if bytes_in == 0:
            # operand type not printed: infer the shard from the output
            bytes_in = bytes_out // n if kind == "all-gather" else bytes_out
        ops.append(CollectiveOp(kind, bytes_in, bytes_out, n,
                                _ring_wire_bytes(kind, bytes_in, bytes_out, n)))
    return ops


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float               # per-chip
    hlo_bytes: float               # per-chip HBM traffic (fusion-optimistic)
    collective_wire_bytes: float   # per-chip
    hlo_bytes_upper: float = 0.0   # CPU-granularity upper bound
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    model_flops: float = 0.0       # 6*N*D (dense) / 6*N_active*D (MoE), per chip
    peak_flops: float = hardware.PEAK_FLOPS
    hbm_bw: float = hardware.HBM_BW
    ici_bw: float = hardware.ICI_BW
    # memory_analysis numbers (per chip)
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0
    peak_hbm_bytes: int = 0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_wire_bytes / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time if the three terms overlap perfectly."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        """Upper bound: no overlap at all."""
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominating roof the *useful* model flops achieve,
        assuming perfect overlap: MODEL_FLOPs/peak / t_bound."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.peak_flops) / self.t_bound

    def summary(self) -> str:
        return (f"{self.arch:>18s} {self.shape:<12s} {self.mesh:<10s} "
                f"compute={self.t_compute*1e3:9.3f}ms "
                f"memory={self.t_memory*1e3:9.3f}ms "
                f"collective={self.t_collective*1e3:9.3f}ms "
                f"bound={self.bottleneck:<10s} "
                f"useful={self.useful_flops_ratio:6.1%} "
                f"roofline={self.roofline_fraction:6.1%}")

    def to_json(self) -> str:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return json.dumps(d, indent=1, sort_keys=True)


def analyze(*, arch: str, shape: str, mesh_name: str, n_chips: int,
            cost: Dict[str, float], hlo_text: str,
            memory: Optional[object] = None,
            model_flops_total: float = 0.0) -> RooflineReport:
    """Build a RooflineReport from compiled-artifact outputs.

    ``cost`` is ``compiled.cost_analysis()`` (per-device).  ``hlo_text`` is
    ``compiled.as_text()``.  ``model_flops_total`` is the whole-job analytic
    6ND flops; it is divided by n_chips here.
    """
    ops = parse_collectives(hlo_text)
    counts: Dict[str, int] = {}
    by_kind: Dict[str, float] = {}
    wire = 0.0
    for op in ops:
        counts[op.kind] = counts.get(op.kind, 0) + 1
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + op.wire_bytes
        wire += op.wire_bytes
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_wire_bytes=wire,
        collective_counts=counts,
        collective_bytes_by_kind=by_kind,
        model_flops=model_flops_total / max(n_chips, 1),
    )
    if memory is not None:
        rep.arg_bytes = int(getattr(memory, "argument_size_in_bytes", 0))
        rep.out_bytes = int(getattr(memory, "output_size_in_bytes", 0))
        rep.temp_bytes = int(getattr(memory, "temp_size_in_bytes", 0))
        rep.peak_hbm_bytes = rep.arg_bytes + rep.out_bytes + rep.temp_bytes
    return rep


def model_flops(n_params: int, n_tokens: int, mode: str = "train") -> float:
    """Analytic useful flops: 6*N*D training, 2*N*D inference forward."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * float(n_params) * float(n_tokens)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_chips: int, model_flops_total: float = 0.0,
                     memory: Optional[object] = None) -> RooflineReport:
    """Loop-aware roofline from a compiled executable (scan bodies scaled by
    their trip counts — see core.hlo_cost)."""
    from .hlo_cost import cost_with_loops
    c = cost_with_loops(compiled)
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=c.flops, hlo_bytes=c.bytes_fused,
        collective_wire_bytes=c.wire_bytes,
        collective_counts=dict(c.collective_counts),
        collective_bytes_by_kind=dict(c.collective_bytes),
        model_flops=model_flops_total / max(n_chips, 1),
    )
    rep.hlo_bytes_upper = c.bytes
    if memory is None and hasattr(compiled, "memory_analysis"):
        try:
            memory = compiled.memory_analysis()
        except Exception:
            memory = None
    if memory is not None:
        rep.arg_bytes = int(getattr(memory, "argument_size_in_bytes", 0))
        rep.out_bytes = int(getattr(memory, "output_size_in_bytes", 0))
        rep.temp_bytes = int(getattr(memory, "temp_size_in_bytes", 0))
        rep.peak_hbm_bytes = rep.arg_bytes + rep.out_bytes + rep.temp_bytes
    return rep
