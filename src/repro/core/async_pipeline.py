"""The paper's asynchronous-copy patterns as composable Pallas TPU emitters.

This is the core contribution adapted to TPU: the A100 ``cp.async``
(global -> shared memory, register-bypassing, overlappable with compute)
becomes the TPU async DMA (HBM -> VMEM via ``pltpu.make_async_copy`` + DMA
semaphores).  The paper's Algorithms 1-3 map to four selectable strategies:

  Strategy.SYNC            GPU baseline: copy, wait, *stage through a second
                           VMEM buffer* (models the register round-trip),
                           compute.  DMA engine idle during compute.
  Strategy.REGISTER_BYPASS Alg. 1: copy, wait, compute directly on the DMA
                           landing buffer.  No overlap, no staging copy.
  Strategy.OVERLAP         Alg. 2: k-slot ring buffer, tile i+k-1 in flight
                           while tile i computes; wait placed *before* compute
                           (the paper's block-synchronization point).
  Strategy.DROP_OFF        Alg. 3: sub-tile chunks; wait for chunk c, read it
                           into VREG values, issue chunk c+1's DMA *before*
                           computing on c.  No tile-level barrier.

Kernels receive a ``TileStream`` per HBM operand and drive it through one of
the ``emit_*`` loop builders below, or hand-roll the pattern when their data
flow does not fit (wavefront kernels).  Everything here works identically in
``interpret=True`` mode on CPU, which is how tests validate the kernels.
"""
from __future__ import annotations

import enum
import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# jax renamed pltpu.TPUCompilerParams -> CompilerParams around 0.5; kernels
# build their compiler_params through this alias so either version works.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics: Tuple[str, ...]):
    return CompilerParams(dimension_semantics=dimension_semantics)


class Strategy(enum.Enum):
    SYNC = "sync"
    REGISTER_BYPASS = "register_bypass"
    OVERLAP = "overlap"
    DROP_OFF = "drop_off"


ALL_STRATEGIES: Tuple[Strategy, ...] = tuple(Strategy)


def parse_strategy(name: str) -> Strategy:
    return Strategy(name)


@dataclass
class TileStream:
    """Binds one HBM operand to a VMEM ring buffer + DMA semaphores.

    ``hbm``      HBM ref (BlockSpec memory_space=pl.ANY)
    ``vmem``     VMEM scratch shaped (depth, *tile_shape)
    ``sem``      DMA semaphore array shaped (depth,)
    ``index``    tile_index -> tuple of pl.ds()/slices into ``hbm``
    """
    hbm: Any
    vmem: Any
    sem: Any
    index: Callable[[Any], Tuple]
    depth: int

    def copy(self, i, slot):
        return pltpu.make_async_copy(
            self.hbm.at[self.index(i)], self.vmem.at[slot], self.sem.at[slot])

    def start(self, i, slot):
        self.copy(i, slot).start()

    def wait(self, i, slot):
        self.copy(i, slot).wait()


def _slot(i, depth: int):
    return jax.lax.rem(i, depth) if depth > 1 else 0


def _when(cond):
    """pl.when that also accepts static python bools (n_tiles may be traced)."""
    if isinstance(cond, bool):
        def deco(f):
            return f() if cond else None
        return deco
    return pl.when(cond)


# ---------------------------------------------------------------------------
# Loop emitters.  ``compute(i, bufs)`` receives the tile index and one VMEM
# ref per stream and must write its own outputs (to an output stream's VMEM
# or directly to an output HBM ref via a write-back TileStream).
# ---------------------------------------------------------------------------

def emit_sync(streams: Sequence[TileStream], n_tiles: int,
              compute: Callable, *, staging: Optional[Sequence[Any]] = None):
    """Paper baseline.  Single-buffered; if ``staging`` VMEM refs are given,
    each tile is copied VMEM->VMEM first (the register-round-trip model)."""
    def body(i, _):
        for s in streams:
            s.start(i, 0)
        for s in streams:
            s.wait(i, 0)
        if staging is not None:
            for s, stage in zip(streams, staging):
                stage[...] = s.vmem[0]
            compute(i, [stage for stage in staging])
        else:
            compute(i, [s.vmem.at[0] for s in streams])
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit_register_bypass(streams: Sequence[TileStream], n_tiles: int,
                         compute: Callable):
    """Alg. 1: async copy direct to VMEM, immediate wait, compute in place."""
    emit_sync(streams, n_tiles, compute, staging=None)


def emit_overlap(streams: Sequence[TileStream], n_tiles: int,
                 compute: Callable, *, depth: int):
    """Alg. 2: ``depth``-deep multibuffered pipeline with prefetch."""
    assert depth >= 2, "overlap needs a ring buffer of depth >= 2"
    # warm-up: issue the first depth-1 copies (static unroll keeps slots
    # static; guards allow a traced n_tiles)
    for j in range(depth - 1):
        @_when(j < n_tiles)
        def _(j=j):
            for s in streams:
                s.start(j, j % depth)

    def body(i, _):
        slot = _slot(i, depth)
        nxt = _slot(i + depth - 1, depth)
        @pl.when(i + depth - 1 < n_tiles)
        def _():
            for s in streams:
                s.start(i + depth - 1, nxt)
        for s in streams:
            s.wait(i, slot)
        compute(i, [s.vmem.at[slot] for s in streams])
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit_drop_off(streams: Sequence[TileStream], n_tiles: int,
                  compute_value: Callable, *, depth: int = 2):
    """Alg. 3 (TPU analogue): double-buffer at *chunk* granularity; after the
    wait, the chunk is read into VREG values and the next DMA is issued
    *before* computing.  ``compute_value(i, vals)`` receives jnp arrays (the
    "registers") and returns nothing (it writes outputs itself)."""
    assert depth >= 2
    @_when(0 < n_tiles)
    def _():
        for s in streams:
            s.start(0, 0)

    def body(i, _):
        slot = _slot(i, depth)
        nxt = _slot(i + 1, depth)
        for s in streams:
            s.wait(i, slot)
        # "drop off" into registers
        vals = [s.vmem[slot] for s in streams]
        # issue the next copy before computing (no block-level barrier)
        @pl.when(i + 1 < n_tiles)
        def _():
            for s in streams:
                s.start(i + 1, nxt)
        compute_value(i, vals)
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit(strategy: Strategy, streams: Sequence[TileStream], n_tiles: int,
         compute: Callable, *, depth: int = 2,
         staging: Optional[Sequence[Any]] = None):
    """Dispatch a loop under the requested strategy.

    ``compute(i, bufs)`` gets VMEM refs for SYNC/REGISTER_BYPASS/OVERLAP and
    jnp values for DROP_OFF (register semantics).
    """
    if strategy == Strategy.SYNC:
        emit_sync(streams, n_tiles, compute, staging=staging)
    elif strategy == Strategy.REGISTER_BYPASS:
        emit_register_bypass(streams, n_tiles, compute)
    elif strategy == Strategy.OVERLAP:
        emit_overlap(streams, n_tiles, compute, depth=max(depth, 2))
    elif strategy == Strategy.DROP_OFF:
        emit_drop_off(streams, n_tiles, compute, depth=max(depth, 2))
    else:  # pragma: no cover
        raise ValueError(strategy)


@dataclass
class WriteBack:
    """Double-buffered VMEM -> HBM result drain (the output-side Overlap).

    ``vmem`` shaped (depth, *tile_shape); ``index(i)`` gives the HBM slice
    for tile i.  ``push(i, val)`` recycles slots, waiting only when the slot's
    previous DMA is still in flight; call ``drain(n_tiles)`` after the loop.
    """
    hbm: Any
    vmem: Any
    sem: Any
    index: Callable[[Any], Tuple]
    depth: int = 2

    def _copy(self, i, slot):
        return pltpu.make_async_copy(
            self.vmem.at[slot], self.hbm.at[self.index(i)], self.sem.at[slot])

    def push(self, i, val):
        slot = _slot(i, self.depth)
        @pl.when(i >= self.depth)
        def _():
            self._copy(i - self.depth, slot).wait()
        self.vmem[slot] = val
        self._copy(i, slot).start()

    def drain(self, n_tiles: int):
        for j in range(min(self.depth, n_tiles)):
            i = n_tiles - 1 - j
            self._copy(i, _slot(i, self.depth)).wait()


def ring_scratch(depth: int, tile_shape: Tuple[int, ...], dtype) -> Any:
    """VMEM ring-buffer scratch shape for a TileStream."""
    return pltpu.VMEM((depth, *tile_shape), dtype)


def dma_sems(depth: int) -> Any:
    return pltpu.SemaphoreType.DMA((depth,))


def scratch_for(strategy: Strategy, tile_shape: Tuple[int, ...], dtype,
                *, depth: int = 2):
    """(vmem_scratch, sem_scratch, effective_depth) for a strategy."""
    d = 1 if strategy in (Strategy.SYNC, Strategy.REGISTER_BYPASS) else max(depth, 2)
    return ring_scratch(d, tile_shape, dtype), dma_sems(d), d
