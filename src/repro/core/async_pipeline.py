"""The paper's asynchronous-copy patterns as composable Pallas TPU emitters.

This is the core contribution adapted to TPU: the A100 ``cp.async``
(global -> shared memory, register-bypassing, overlappable with compute)
becomes the TPU async DMA (HBM -> VMEM via ``pltpu.make_async_copy`` + DMA
semaphores).  The paper's Algorithms 1-3 map to four selectable strategies:

  Strategy.SYNC            GPU baseline: copy, wait, *stage through a second
                           VMEM buffer* (models the register round-trip),
                           compute.  DMA engine idle during compute.
  Strategy.REGISTER_BYPASS Alg. 1: copy, wait, compute directly on the DMA
                           landing buffer.  No overlap, no staging copy.
  Strategy.OVERLAP         Alg. 2: k-slot ring buffer, up to ``wait_group``
                           copies in flight while tile i computes; wait
                           placed *before* compute (the paper's
                           block-synchronization point).
  Strategy.DROP_OFF        Alg. 3: sub-tile chunks; wait for chunk c, read it
                           into VREG values, issue the next DMA *before*
                           computing on c.  No tile-level barrier.
  Strategy.TMA             Hopper-style bulk copies (Luo et al.,
                           arXiv:2402.13499 / 2501.12084): one descriptor-
                           issued 1D/2D bulk copy per operand tile, all
                           operands of a tile completing on one shared
                           per-slot barrier semaphore (the mbarrier
                           arrive/expect-tx analogue) instead of per-copy
                           wait groups.  The consumer posts a single
                           grouped wait per tile and computes directly in
                           the landing buffer; the ring always runs at its
                           deepest issue-ahead (``depth - 1``) because the
                           mbarrier decouples producer issue from consumer
                           waits — ``wait_group`` does not apply.

The pipeline *shape* is a first-class value, ``PipelineSpec``:

  ``depth``       VMEM ring-buffer slots (N-stage pipeline, not just double
                  buffering)
  ``wait_group``  how many copies may still be in flight when compute on
                  tile i begins — the TPU analogue of ``cp.async.wait_group
                  N``.  ``None`` means the deepest safe value, ``depth - 1``.
  ``out_depth``   write-back ring slots for the ``WriteBack`` drain

Kernels receive a ``TileStream`` per HBM operand and drive it through one of
the ``emit_*`` loop builders below (normally via ``emit(spec, ...)``), or
hand-roll the pattern when their data flow does not fit (wavefront kernels).
Everything here works identically in ``interpret=True`` mode on CPU, which
is how tests validate the kernels.
"""
from __future__ import annotations

import enum
import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# jax renamed pltpu.TPUCompilerParams -> CompilerParams around 0.5; kernels
# build their compiler_params through this alias so either version works.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics: Tuple[str, ...]):
    return CompilerParams(dimension_semantics=dimension_semantics)


class Strategy(enum.Enum):
    SYNC = "sync"
    REGISTER_BYPASS = "register_bypass"
    OVERLAP = "overlap"
    DROP_OFF = "drop_off"
    TMA = "tma"


ALL_STRATEGIES: Tuple[Strategy, ...] = tuple(Strategy)


def parse_strategy(name: Union[str, Strategy]) -> Strategy:
    """Parse a strategy name, case-insensitively; the error names the valid
    choices so a CLI ``--strategy`` typo is self-explaining."""
    if isinstance(name, Strategy):
        return name
    try:
        return Strategy(str(name).strip().lower())
    except ValueError:
        valid = ", ".join(s.value for s in Strategy)
        raise ValueError(
            f"unknown strategy {name!r}; valid strategies: {valid}") from None


_SINGLE_BUFFERED = (Strategy.SYNC, Strategy.REGISTER_BYPASS)


@dataclass(frozen=True)
class PipelineSpec:
    """The shape of one kernel's async pipeline — strategy, input ring depth,
    wait-group depth, and output (write-back) ring depth.

    Frozen and hashable so it can travel through jit static arguments.
    ``wait_group`` caps how many input copies may remain in flight when the
    wait for tile i is posted (``cp.async.wait_group N`` on A100): the
    emitters issue tile ``i + A`` before waiting tile ``i`` where
    ``A = min(wait_group, depth - 1)``; ``wait_group=None`` means the
    deepest safe issue-ahead, ``depth - 1``.
    """
    strategy: Strategy = Strategy.OVERLAP
    depth: int = 2
    wait_group: Optional[int] = None
    out_depth: int = 2

    def __post_init__(self):
        # accept strategy names ("overlap") anywhere a spec is built —
        # wrappers and tuned configs carry strings through jit static args
        object.__setattr__(self, "strategy", parse_strategy(self.strategy))
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.wait_group is not None and self.wait_group < 0:
            raise ValueError(
                f"wait_group must be >= 0 or None, got {self.wait_group}")
        if self.out_depth < 1:
            raise ValueError(f"out_depth must be >= 1, got {self.out_depth}")

    @property
    def ring_depth(self) -> int:
        """Input VMEM ring slots actually allocated: single-buffered
        strategies take one slot; async strategies at least two."""
        return 1 if self.strategy in _SINGLE_BUFFERED else max(self.depth, 2)

    @property
    def ahead(self) -> int:
        """Issue-ahead distance A: tile i+A is started before tile i's wait.
        Equivalently, at most A copies are in flight during compute on i.
        TMA always runs at the deepest issue-ahead: its mbarrier counts
        transaction arrivals per slot, so there is no wait-group axis."""
        if self.strategy in _SINGLE_BUFFERED:
            return 0
        limit = self.ring_depth - 1
        if self.strategy is Strategy.TMA:
            return limit
        return limit if self.wait_group is None \
            else max(0, min(self.wait_group, limit))

    @classmethod
    def from_config(cls, config: dict) -> "PipelineSpec":
        """Build a spec from a flat kernel-config dict (KERNEL_DEFAULTS /
        tuning-registry style); unrelated keys are ignored."""
        wg = config.get("wait_group")
        return cls(strategy=parse_strategy(
                       config.get("strategy", Strategy.OVERLAP)),
                   depth=int(config.get("depth", 2)),
                   wait_group=None if wg is None else int(wg),
                   out_depth=int(config.get("out_depth", 2)))


def as_spec(spec: Union[PipelineSpec, Strategy], *, depth: int = 2,
            wait_group: Optional[int] = None,
            out_depth: int = 2) -> PipelineSpec:
    """Coerce a bare Strategy (legacy call style) into a PipelineSpec."""
    if isinstance(spec, PipelineSpec):
        return spec
    return PipelineSpec(strategy=spec, depth=depth, wait_group=wait_group,
                        out_depth=out_depth)


@dataclass
class TileStream:
    """Binds one HBM operand to a VMEM ring buffer + DMA semaphores.

    ``hbm``      HBM ref (BlockSpec memory_space=pl.ANY)
    ``vmem``     VMEM scratch shaped (depth, *tile_shape)
    ``sem``      DMA semaphore array shaped (depth,)
    ``index``    tile_index -> tuple of pl.ds()/slices into ``hbm``
    """
    hbm: Any
    vmem: Any
    sem: Any
    index: Callable[[Any], Tuple]
    depth: int

    def copy(self, i, slot):
        return pltpu.make_async_copy(
            self.hbm.at[self.index(i)], self.vmem.at[slot], self.sem.at[slot])

    def start(self, i, slot):
        self.copy(i, slot).start()

    def wait(self, i, slot):
        self.copy(i, slot).wait()


def _slot(i, depth: int):
    return jax.lax.rem(i, depth) if depth > 1 else 0


def _when(cond):
    """pl.when that also accepts static python bools (n_tiles may be traced)."""
    if isinstance(cond, bool):
        def deco(f):
            return f() if cond else None
        return deco
    return pl.when(cond)


def _issue_ahead(depth: int, wait_group: Optional[int]) -> int:
    limit = depth - 1
    return limit if wait_group is None else max(0, min(wait_group, limit))


def _warm_idx(j: int, n_tiles):
    """Warm-up tile index that is safe to *trace* when ``n_tiles`` is traced.

    With a static ``n_tiles`` the ``_when`` guard skips tracing entirely, so
    the static ``j`` is known in-bounds.  With a traced ``n_tiles`` the
    guarded branch still traces, and a static ``j`` past the HBM extent
    would fail Pallas's static slice validation — clamping through the
    traced bound makes the slice dynamic (runtime execution is already
    prevented by the guard)."""
    if isinstance(n_tiles, int):
        return j
    return jnp.minimum(j, n_tiles - 1)


# ---------------------------------------------------------------------------
# Loop emitters.  ``compute(i, bufs)`` receives the tile index and one VMEM
# ref per stream and must write its own outputs (to an output stream's VMEM
# or directly to an output HBM ref via a write-back TileStream).
# ---------------------------------------------------------------------------

def emit_sync(streams: Sequence[TileStream], n_tiles: int,
              compute: Callable, *, staging: Optional[Sequence[Any]] = None):
    """Paper baseline.  Single-buffered; if ``staging`` VMEM refs are given,
    each tile is copied VMEM->VMEM first (the register-round-trip model)."""
    def body(i, _):
        for s in streams:
            s.start(i, 0)
        for s in streams:
            s.wait(i, 0)
        if staging is not None:
            for s, stage in zip(streams, staging):
                stage[...] = s.vmem[0]
            compute(i, [stage for stage in staging])
        else:
            compute(i, [s.vmem.at[0] for s in streams])
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit_register_bypass(streams: Sequence[TileStream], n_tiles: int,
                         compute: Callable):
    """Alg. 1: async copy direct to VMEM, immediate wait, compute in place."""
    emit_sync(streams, n_tiles, compute, staging=None)


def emit_overlap(streams: Sequence[TileStream], n_tiles: int,
                 compute: Callable, *, depth: int,
                 wait_group: Optional[int] = None):
    """Alg. 2: N-stage ring pipeline with grouped waits.

    Tile ``i + A`` is issued before tile ``i``'s wait, with
    ``A = min(wait_group, depth - 1)`` copies in flight during each compute
    (``wait_group=None`` -> the deepest safe ``depth - 1``).  Slot reuse is
    safe because tile ``i + A`` lands in the slot of tile ``i + A - depth``,
    whose compute finished at least one iteration ago (``A <= depth - 1``).
    """
    assert depth >= 2, "overlap needs a ring buffer of depth >= 2"
    ahead = _issue_ahead(depth, wait_group)
    # warm-up: issue the first `ahead` copies (static unroll keeps slots
    # static; guards allow a traced n_tiles)
    for j in range(ahead):
        @_when(j < n_tiles)
        def _(j=j):
            for s in streams:
                s.start(_warm_idx(j, n_tiles), j % depth)

    def body(i, _):
        slot = _slot(i, depth)
        if ahead:
            nxt = _slot(i + ahead, depth)
            @pl.when(i + ahead < n_tiles)
            def _():
                for s in streams:
                    s.start(i + ahead, nxt)
        else:                           # wait_group=0: degenerate, no overlap
            for s in streams:
                s.start(i, slot)
        for s in streams:
            s.wait(i, slot)
        compute(i, [s.vmem.at[slot] for s in streams])
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit_drop_off(streams: Sequence[TileStream], n_tiles: int,
                  compute_value: Callable, *, depth: int = 2,
                  wait_group: Optional[int] = None):
    """Alg. 3 (TPU analogue): ring-buffer at *chunk* granularity; after the
    wait, the chunk is read into VREG values and the next DMA is issued
    *before* computing.  ``compute_value(i, vals)`` receives jnp arrays (the
    "registers") and returns nothing (it writes outputs itself).  The same
    ``wait_group`` issue-ahead rule as ``emit_overlap`` applies; the
    defining difference is that the next copy is posted only after the
    current chunk has been dropped off into registers."""
    assert depth >= 2
    ahead = _issue_ahead(depth, wait_group)
    for j in range(ahead):
        @_when(j < n_tiles)
        def _(j=j):
            for s in streams:
                s.start(_warm_idx(j, n_tiles), j % depth)

    def body(i, _):
        slot = _slot(i, depth)
        if ahead == 0:
            for s in streams:
                s.start(i, slot)
        for s in streams:
            s.wait(i, slot)
        # "drop off" into registers
        vals = [s.vmem[slot] for s in streams]
        # issue the next copy before computing (no block-level barrier)
        if ahead:
            nxt = _slot(i + ahead, depth)
            @pl.when(i + ahead < n_tiles)
            def _():
                for s in streams:
                    s.start(i + ahead, nxt)
        compute_value(i, vals)
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit_tma(streams: Sequence[TileStream], n_tiles: int,
             compute: Callable, *, depth: int):
    """Hopper-TMA analogue: bulk descriptor copies completing on a shared
    per-slot barrier.

    Every operand tile moves as one 1D/2D bulk copy (the TileStream slice is
    the copy descriptor), and *all* operands of tile ``i`` signal the same
    per-slot semaphore — the mbarrier ``expect-tx`` pattern: the consumer
    posts one grouped wait of ``len(streams)`` arrivals instead of one wait
    per copy, then computes directly in the landing buffer (register-
    bypassing, like ``cp.async``, but descriptor-issued from a single
    producer).  Because the barrier decouples issue from consumption, the
    ring always runs at its deepest issue-ahead ``depth - 1``; there is no
    wait-group axis (``PipelineSpec.wait_group`` is ignored).

    ``streams[0].sem`` serves as the slot barrier array; the other streams'
    semaphores are left untouched so kernel scratch arity stays identical
    across strategies.
    """
    assert depth >= 2, "tma needs a ring buffer of depth >= 2"
    bar = streams[0].sem            # per-slot transaction barrier (mbarrier)

    def bulk_copy(s: TileStream, i, slot):
        return pltpu.make_async_copy(
            s.hbm.at[s.index(i)], s.vmem.at[slot], bar.at[slot])

    ahead = depth - 1
    for j in range(ahead):
        @_when(j < n_tiles)
        def _(j=j):
            for s in streams:
                bulk_copy(s, _warm_idx(j, n_tiles), j % depth).start()

    def body(i, _):
        slot = _slot(i, depth)
        nxt = _slot(i + ahead, depth)
        @pl.when(i + ahead < n_tiles)
        def _():
            for s in streams:
                bulk_copy(s, i + ahead, nxt).start()
        # the grouped mbarrier wait: one arrival per operand bulk copy
        for s in streams:
            bulk_copy(s, i, slot).wait()
        compute(i, [s.vmem.at[slot] for s in streams])
        return ()
    jax.lax.fori_loop(0, n_tiles, body, ())


def emit(spec: Union[PipelineSpec, Strategy], streams: Sequence[TileStream],
         n_tiles: int, compute: Callable, *, depth: int = 2,
         staging: Optional[Sequence[Any]] = None):
    """Dispatch a loop under the requested pipeline spec (or bare Strategy,
    in which case ``depth`` applies and wait_group defaults).

    ``compute(i, bufs)`` gets VMEM refs for SYNC/REGISTER_BYPASS/OVERLAP/TMA
    and jnp values for DROP_OFF (register semantics).  ``staging`` is
    consumed only by SYNC (the register-round-trip model) and may be passed
    unconditionally.
    """
    spec = as_spec(spec, depth=depth)
    if spec.strategy == Strategy.SYNC:
        emit_sync(streams, n_tiles, compute, staging=staging)
    elif spec.strategy == Strategy.REGISTER_BYPASS:
        emit_register_bypass(streams, n_tiles, compute)
    elif spec.strategy == Strategy.OVERLAP:
        emit_overlap(streams, n_tiles, compute, depth=spec.ring_depth,
                     wait_group=spec.wait_group)
    elif spec.strategy == Strategy.DROP_OFF:
        emit_drop_off(streams, n_tiles, compute, depth=spec.ring_depth,
                      wait_group=spec.wait_group)
    elif spec.strategy == Strategy.TMA:
        emit_tma(streams, n_tiles, compute, depth=spec.ring_depth)
    else:  # pragma: no cover
        raise ValueError(spec.strategy)


@dataclass
class WriteBack:
    """N-deep VMEM -> HBM result drain (the output-side Overlap).

    ``vmem`` shaped (depth, *tile_shape); ``index(i)`` gives the HBM slice
    for tile i.  ``push(i, val)`` recycles slots, waiting only when the slot's
    previous DMA is still in flight; call ``drain(n_tiles)`` after the loop
    (``n_tiles`` may be traced — the guards become ``pl.when``)."""
    hbm: Any
    vmem: Any
    sem: Any
    index: Callable[[Any], Tuple]
    depth: int = 2

    def _copy(self, i, slot):
        return pltpu.make_async_copy(
            self.vmem.at[slot], self.hbm.at[self.index(i)], self.sem.at[slot])

    def push(self, i, val):
        slot = _slot(i, self.depth)
        @_when(i >= self.depth)
        def _():
            self._copy(i - self.depth, slot).wait()
        self.vmem[slot] = val
        self._copy(i, slot).start()

    def drain(self, n_tiles):
        for j in range(self.depth):
            @_when(j < n_tiles)
            def _(j=j):
                i = n_tiles - 1 - j
                self._copy(i, _slot(i, self.depth)).wait()


def ring_scratch(depth: int, tile_shape: Tuple[int, ...], dtype) -> Any:
    """VMEM ring-buffer scratch shape for a TileStream."""
    return pltpu.VMEM((depth, *tile_shape), dtype)


def dma_sems(depth: int) -> Any:
    return pltpu.SemaphoreType.DMA((depth,))


def scratch_for(spec: Union[PipelineSpec, Strategy],
                tile_shape: Tuple[int, ...], dtype, *, depth: int = 2):
    """(vmem_ring, dma_sems, staging) scratch specs for one TileStream.

    ``staging`` is the SYNC register-round-trip buffer (full tile shape so
    ``emit_sync(..., staging=...)`` can land the VMEM->VMEM copy); for every
    other strategy it is a minimal placeholder so kernel scratch arity stays
    the same across strategies.  Kernels must not hand-roll staging buffers.
    """
    spec = as_spec(spec, depth=depth)
    stage_shape = tile_shape if spec.strategy == Strategy.SYNC \
        else tuple(1 for _ in tile_shape)
    return (ring_scratch(spec.ring_depth, tile_shape, dtype),
            dma_sems(spec.ring_depth),
            pltpu.VMEM(stage_shape, dtype))


def writeback_scratch(spec: Union[PipelineSpec, Strategy],
                      tile_shape: Tuple[int, ...], dtype):
    """(vmem_ring, dma_sems) for a WriteBack drain at ``spec.out_depth``."""
    d = spec.out_depth if isinstance(spec, PipelineSpec) else 2
    return ring_scratch(d, tile_shape, dtype), dma_sems(d)
