"""Machine-balance analysis (paper Fig. 1 + §6 expectation model).

The paper derives, for each chip:
  * machine balance B/F = memory_bandwidth / peak_flops  (fp32 and fp64),
  * compute density = FLOPS / mm^2,
and from any pair (old, new) the *expected minimum speedup*

    T_speedup = min(FLOP_new/FLOP_old, BW_new/BW_old)

which holds regardless of whether an application is compute- or memory-bound
(paper §6: V100→A100 gives min(1.38, 1.73) = 1.38x — and Rodinia measured 1.34x,
i.e. the A100 under-delivers). This module reproduces those derivations and is
validated against the paper's reported ratios in tests/test_balance.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .hardware import Chip, CATALOG


@dataclass(frozen=True)
class Balance:
    """Per-chip derivations.  f64 fields are NaN ("n/a") for chips without
    f64 units; density fields are NaN when the die area is unpublished —
    renderers must print "n/a" for NaN, never a number."""
    name: str
    bf_f32: float                # bytes per fp32 flop
    bf_f64: float                # NaN when the chip has no f64 units
    density_f32: float           # GFLOPS / mm^2; NaN when die unpublished
    density_f64: float


def machine_balance(chip: Chip) -> Balance:
    bf32 = chip.mem_bw_gbs / (chip.tflops_f32 * 1e3)
    bf64 = chip.mem_bw_gbs / (chip.tflops_f64 * 1e3) if chip.has_f64 \
        else float("nan")
    d32 = chip.tflops_f32 * 1e3 / chip.die_mm2 if chip.density_known \
        else float("nan")
    d64 = (chip.tflops_f64 * 1e3 / chip.die_mm2
           if chip.density_known and chip.has_f64 else float("nan"))
    return Balance(chip.name, bf32, bf64, d32, d64)


_PRECISIONS = ("f32", "f64")


def _flops_at(chip: Chip, precision: str) -> float:
    """Peak TFLOPs at ``precision``; raises for unknown precisions and for
    f64 on chips without f64 units (instead of silently dividing by the
    0.0 sentinel into inf/nan ratios)."""
    if precision not in _PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"valid: {_PRECISIONS}")
    if precision == "f64":
        if not chip.has_f64:
            raise ValueError(
                f"{chip.name} has no f64 units; f64 ratios are undefined "
                "(use precision='f32' for the lineage metric)")
        return chip.tflops_f64
    return chip.tflops_f32


@dataclass(frozen=True)
class SpeedupExpectation:
    """The §6 expectation, kept with both ratio terms so a report can say
    *which* roofline ceiling binds, not just the min."""
    old: str
    new: str
    precision: str
    flop_ratio: float
    bw_ratio: float

    @property
    def expected(self) -> float:
        return min(self.flop_ratio, self.bw_ratio)

    @property
    def binds(self) -> str:
        """Which term limits the expected speedup."""
        return "flops" if self.flop_ratio <= self.bw_ratio else "bandwidth"


def expect_speedup(old: Chip, new: Chip,
                   precision: str = "f32") -> SpeedupExpectation:
    """Paper §6 expectation with both terms.  Raises ``ValueError`` when
    ``precision='f64'`` and either chip lacks f64 units (TPUs)."""
    flop_ratio = _flops_at(new, precision) / _flops_at(old, precision)
    bw_ratio = new.mem_bw_gbs / old.mem_bw_gbs
    return SpeedupExpectation(old.name, new.name, precision,
                              flop_ratio, bw_ratio)


def expected_speedup(old: Chip, new: Chip, precision: str = "f32") -> float:
    """Paper §6: T_speedup = min(FLOP ratio, BW ratio).

    Raises ``ValueError`` for ``precision='f64'`` when either chip has no
    f64 units (every TPU) — the ratio used to silently become inf/nan."""
    return expect_speedup(old, new, precision).expected


def roofline_time(flops: float, bytes_moved: float, chip: Chip,
                  precision: str = "f32") -> float:
    """Classic 2-term roofline execution-time estimate (seconds) on one chip.
    Raises for f64 on chips without f64 units (same contract as
    ``expected_speedup``)."""
    peak = _flops_at(chip, precision) * 1e12
    t_compute = flops / peak
    t_memory = bytes_moved / (chip.mem_bw_gbs * 1e9)
    return max(t_compute, t_memory)


def attainable_flops(intensity: float, chip: Chip, precision: str = "f32") -> float:
    """Roofline attainable FLOP/s at a given arithmetic intensity (flops/byte)."""
    peak = _flops_at(chip, precision) * 1e12
    return min(peak, intensity * chip.mem_bw_gbs * 1e9)


def ridge_point(chip: Chip, precision: str = "f32") -> float:
    """Arithmetic intensity (flops/byte) where the roofline bends."""
    peak = _flops_at(chip, precision) * 1e12
    return peak / (chip.mem_bw_gbs * 1e9)


def lineage_table() -> Dict[str, Balance]:
    """Balance derivations for every catalog chip.  (A ``precision``
    parameter used to be accepted and silently ignored — ``Balance`` always
    carries both precisions; tests/test_balance.py pins this signature.)"""
    return {name: machine_balance(chip) for name, chip in CATALOG.items()}
