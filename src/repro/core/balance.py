"""Machine-balance analysis (paper Fig. 1 + §6 expectation model).

The paper derives, for each chip:
  * machine balance B/F = memory_bandwidth / peak_flops  (fp32 and fp64),
  * compute density = FLOPS / mm^2,
and from any pair (old, new) the *expected minimum speedup*

    T_speedup = min(FLOP_new/FLOP_old, BW_new/BW_old)

which holds regardless of whether an application is compute- or memory-bound
(paper §6: V100→A100 gives min(1.38, 1.73) = 1.38x — and Rodinia measured 1.34x,
i.e. the A100 under-delivers). This module reproduces those derivations and is
validated against the paper's reported ratios in tests/test_balance.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .hardware import Chip, CATALOG


@dataclass(frozen=True)
class Balance:
    name: str
    bf_f32: float                # bytes per fp32 flop
    bf_f64: float
    density_f32: float           # GFLOPS / mm^2
    density_f64: float


def machine_balance(chip: Chip) -> Balance:
    bf32 = chip.mem_bw_gbs / (chip.tflops_f32 * 1e3)
    bf64 = chip.mem_bw_gbs / (chip.tflops_f64 * 1e3) if chip.tflops_f64 else float("inf")
    d32 = chip.tflops_f32 * 1e3 / chip.die_mm2 if chip.die_mm2 else float("nan")
    d64 = chip.tflops_f64 * 1e3 / chip.die_mm2 if chip.die_mm2 else float("nan")
    return Balance(chip.name, bf32, bf64, d32, d64)


def expected_speedup(old: Chip, new: Chip, precision: str = "f32") -> float:
    """Paper §6: T_speedup = min(FLOP ratio, BW ratio)."""
    if precision == "f64":
        flop_ratio = new.tflops_f64 / old.tflops_f64
    else:
        flop_ratio = new.tflops_f32 / old.tflops_f32
    bw_ratio = new.mem_bw_gbs / old.mem_bw_gbs
    return min(flop_ratio, bw_ratio)


def roofline_time(flops: float, bytes_moved: float, chip: Chip,
                  precision: str = "f32") -> float:
    """Classic 2-term roofline execution-time estimate (seconds) on one chip."""
    peak = (chip.tflops_f64 if precision == "f64" else chip.tflops_f32) * 1e12
    t_compute = flops / peak
    t_memory = bytes_moved / (chip.mem_bw_gbs * 1e9)
    return max(t_compute, t_memory)


def attainable_flops(intensity: float, chip: Chip, precision: str = "f32") -> float:
    """Roofline attainable FLOP/s at a given arithmetic intensity (flops/byte)."""
    peak = (chip.tflops_f64 if precision == "f64" else chip.tflops_f32) * 1e12
    return min(peak, intensity * chip.mem_bw_gbs * 1e9)


def ridge_point(chip: Chip, precision: str = "f32") -> float:
    """Arithmetic intensity (flops/byte) where the roofline bends."""
    peak = (chip.tflops_f64 if precision == "f64" else chip.tflops_f32) * 1e12
    return peak / (chip.mem_bw_gbs * 1e9)


def lineage_table(precision: str = "f32") -> Dict[str, Balance]:
    return {name: machine_balance(chip) for name, chip in CATALOG.items()}
