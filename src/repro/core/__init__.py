"""Core: the paper's contribution (async data-movement pipelines) plus the
machine-balance / roofline analysis machinery that the lineage study uses."""
from . import async_pipeline, balance, config, hardware, roofline
from .async_pipeline import PipelineSpec, Strategy, parse_strategy
from .config import (ArchConfig, AttnConfig, MoEConfig, RunConfig,
                     ShapeConfig, SSMConfig, SHAPES, get_shape)

__all__ = [
    "async_pipeline", "balance", "config", "hardware", "roofline",
    "PipelineSpec", "Strategy", "parse_strategy",
    "ArchConfig", "AttnConfig", "MoEConfig", "RunConfig",
    "ShapeConfig", "SSMConfig", "SHAPES", "get_shape",
]
