"""Configuration system for the repro framework.

Every architecture / input-shape / mesh combination is described by plain,
hashable dataclasses so configs can be used as jit static arguments, diffed,
serialized into checkpoints, and printed into experiment logs.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    n_experts: int = 0            # routed experts (0 => dense MLP)
    top_k: int = 0
    n_shared: int = 0             # always-on shared experts (qwen2-moe style)
    d_ff_expert: int = 0          # hidden dim of each routed expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block configuration (xLSTM, Mamba)."""
    kind: str = "none"            # "none" | "xlstm" | "mamba"
    d_state: int = 16             # mamba SSM state size
    d_conv: int = 4               # mamba local conv width
    expand: int = 2               # mamba inner expansion
    slstm_every: int = 0          # xlstm: a sLSTM block every N layers (0 => all mLSTM)
    chunk: int = 64               # chunkwise-parallel scan chunk length

    @property
    def enabled(self) -> bool:
        return self.kind != "none"


@dataclass(frozen=True)
class AttnConfig:
    """Per-architecture attention behaviour."""
    kind: str = "full"            # "full" | "sliding" | "none"
    window: int = 0               # sliding-window size (tokens), 0 => full
    chunk: int = 1024             # online-softmax KV chunk for long sequences
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    softcap: float = 0.0          # logit soft-capping (0 => off)

    @property
    def sub_quadratic(self) -> bool:
        return self.kind in ("sliding", "none")


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    act: str = "swiglu"           # swiglu | gelu | relu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    parallel_residual: bool = False   # command-r style parallel attn+mlp
    tie_embeddings: bool = False
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # encoder-decoder (whisper)
    n_enc_layers: int = 0         # 0 => decoder-only
    # multimodal stub frontend
    n_patches: int = 0            # vlm: patch embeddings prepended to the sequence
    # numerics
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (unpadded, matches the published size)."""
        d, hd = self.d_model, self.head_dim_
        nh, nkv = self.n_heads, self.n_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d  # q,k,v,o
        if self.attn.qkv_bias:
            attn += (nh + 2 * nkv) * hd
        if self.moe.enabled:
            e = self.moe
            mlp = e.n_experts * 3 * d * e.d_ff_expert + d * e.n_experts  # experts + router
            mlp += e.n_shared * 3 * d * e.d_ff_expert
        elif self.d_ff > 0:
            n_mat = 3 if self.act == "swiglu" else 2
            mlp = n_mat * d * self.d_ff
        else:
            mlp = 0
        if self.ssm.enabled and self.ssm.kind == "xlstm":
            # mLSTM block: up/z proj + headwise qkv + gates + down proj
            inner = self.ssm.expand * d
            mlp = 0
            attn = (2 * d * inner                      # up, z
                    + 3 * inner * inner // self.n_heads  # headwise qkv
                    + inner * d                        # down
                    + 2 * inner * self.n_heads + 2 * self.n_heads)
        if self.ssm.enabled and self.ssm.kind == "mamba":
            inner = self.ssm.expand * d
            attn += 2 * d * inner + inner * self.ssm.d_state * 2 + inner * d
        per_layer = attn + mlp + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = self.n_layers * per_layer + emb + d
        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn (already in n_layers count)
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec_cross = self.n_layers * (attn + d)
            total += enc + dec_cross
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active params (= total for dense; routed subset for MoE)."""
        if not self.moe.enabled:
            return self.param_count()
        active_cfg = dataclasses.replace(
            self, moe=dataclasses.replace(
                self.moe, n_experts=self.moe.top_k))
        return active_cfg.param_count()


# ---------------------------------------------------------------------------
# Input-shape config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in SHAPES]}")


# ---------------------------------------------------------------------------
# Run config (training/serving hyperparameters independent of the arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    microbatches: int = 1         # gradient-accumulation steps
    remat: bool = True            # activation checkpointing inside the layer scan
    zero1: bool = True            # shard optimizer moments over data axis
    grad_compression: str = "none"  # "none" | "bf16" — cross-replica reduce dtype
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    seed: int = 0
    # serving
    decode_microbatch: int = 0    # 0 => whole batch at once
    # beyond-paper perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    fsdp: bool = False            # shard params over data axis too (ZeRO-3 style)
    seq_shard: bool = False       # sequence-parallel activations for norm/mlp


# ---------------------------------------------------------------------------
# Serialization helpers
# ---------------------------------------------------------------------------

def to_json(cfg) -> str:
    return json.dumps(dataclasses.asdict(cfg), indent=2, sort_keys=True)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
