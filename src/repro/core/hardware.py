"""Hardware catalog.

Reproduces the paper's Table 1 (eight Nvidia GPUs across five generations)
verbatim, extends the Nvidia lineage past the paper's Ampere endpoint with
the Hopper generation (figures from the vendor datasheets as quoted by the
Hopper microbenchmark papers, Luo et al. arXiv:2402.13499 / 2501.12084), and
adds the TPU generations this framework targets — the machine-balance
analysis (paper Fig. 1), the expected-speedup model (paper §6) and the
lineage validation (``repro.bench.lineage``) are computed over these records.

All numbers are peak/vendor figures, matching the paper's methodology
(techpowerup / vendor datasheets).  ``tdp_w`` / ``die_mm2`` may be 0.0 when
the vendor has not published them (recent TPUs); consumers must render such
sentinels as "n/a" — ``core.balance`` reports the derived densities as NaN.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class Chip:
    name: str
    vendor: str
    year: str
    arch: str
    grade: str                     # "datacenter" | "consumer" | "tpu"
    mem_gb: float
    mem_bw_gbs: float              # external memory bandwidth, GB/s
    tflops_f32: float              # fp32 (GPU) / bf16 (TPU — the lineage metric)
    tflops_f64: float              # 0.0 = no f64 units (TPUs)
    n_cores: int                   # SMs (GPU) / TensorCores-per-chip (TPU)
    tdp_w: float                   # 0.0 = unpublished (render as "n/a")
    die_mm2: float                 # 0.0 = unpublished (render as "n/a")
    # interconnect (per-link, unidirectional)
    link_gbs: float = 0.0
    vmem_mb: float = 0.0           # on-chip scratch (shared mem / VMEM)
    # async bulk-copy engine generation (lineage annotation): "" = plain
    # synchronous loads, "cp.async" = Ampere per-thread async copies,
    # "tma" = Hopper bulk tensor-memory accelerator, "dma" = TPU DMA engines
    async_engine: str = ""

    @property
    def has_f64(self) -> bool:
        """Whether the chip has native f64 units (TPUs do not)."""
        return self.tflops_f64 > 0.0

    @property
    def density_known(self) -> bool:
        """Whether die area is published (compute density is derivable)."""
        return self.die_mm2 > 0.0


# --- paper Table 1, verbatim -------------------------------------------------

GPUS: Tuple[Chip, ...] = (
    # Tesla / data-center
    Chip("K80", "nvidia", "2014Q4", "Kepler", "datacenter", 12, 240.6, 4.113, 1.371, 13, 300, 561),
    Chip("P100", "nvidia", "2016Q2", "Pascal", "datacenter", 16, 732.2, 10.61, 5.304, 56, 300, 610),
    Chip("V100", "nvidia", "2017Q3", "Volta", "datacenter", 16, 897.0, 14.13, 7.066, 80, 300, 815),
    Chip("A100", "nvidia", "2020Q3", "Ampere", "datacenter", 40, 1555.0, 19.49, 9.746, 108, 250, 826, async_engine="cp.async"),
    # Workstation / consumer
    Chip("GTX745", "nvidia", "2014Q1", "Maxwell", "consumer", 4, 28.80, 0.793, 0.02479, 3, 55, 148),
    Chip("K2200", "nvidia", "2014Q3", "Maxwell", "consumer", 4, 80.19, 1.439, 0.04496, 5, 68, 148),
    Chip("GTX1050Ti", "nvidia", "2016Q4", "Pascal", "consumer", 4, 112.1, 2.138, 0.0668, 6, 75, 132),
    Chip("RTX2060S", "nvidia", "2019Q3", "Turing", "consumer", 8, 448.0, 7.181, 0.224, 34, 175, 445),
)

# --- Hopper extension (past the paper) ---------------------------------------
# The paper stops at Ampere; these rows extend the datacenter lineage with the
# Hopper generation so the §6 expectation model becomes *predictive*.  Figures
# are vendor datasheet peaks (non-tensor f32/f64 vector throughput, matching
# the Table 1 convention) as quoted by the Hopper microbenchmark papers
# (Luo et al. arXiv:2402.13499, arXiv:2501.12084); the catalog-vs-published
# validation lives in experiments/baselines/LINEAGE_hopper.json +
# repro.bench.lineage.

HOPPER: Tuple[Chip, ...] = (
    Chip("H100-SXM", "nvidia", "2022Q4", "Hopper", "datacenter", 80, 3352.0, 66.91, 33.45, 132, 700, 814, async_engine="tma"),
    Chip("H100-PCIe", "nvidia", "2022Q4", "Hopper", "datacenter", 80, 2039.0, 51.22, 25.61, 114, 350, 814, async_engine="tma"),
    Chip("H200", "nvidia", "2024Q2", "Hopper", "datacenter", 141, 4890.0, 66.91, 33.45, 132, 700, 814, async_engine="tma"),
)

# --- TPU lineage extension ---------------------------------------------------
# tflops_f32 column holds bf16/matmul peak for TPUs (the throughput metric the
# lineage comparison uses); f64 is N/A on TPU (0.0).  TPUv5e/v5p tdp/die are
# unpublished -> 0.0 sentinels (consumers must print "n/a", never divide).

TPUS: Tuple[Chip, ...] = (
    Chip("TPUv2", "google", "2017", "TPUv2", "tpu", 8, 700.0, 45.0, 0.0, 2, 280, 0, link_gbs=62.5, vmem_mb=24, async_engine="dma"),
    Chip("TPUv3", "google", "2018", "TPUv3", "tpu", 16, 900.0, 123.0, 0.0, 2, 220, 0, link_gbs=81.25, vmem_mb=32, async_engine="dma"),
    Chip("TPUv4", "google", "2021", "TPUv4", "tpu", 32, 1200.0, 275.0, 0.0, 2, 170, 0, link_gbs=50.0, vmem_mb=128, async_engine="dma"),
    Chip("TPUv5e", "google", "2023", "TPUv5e", "tpu", 16, 819.0, 197.0, 0.0, 1, 0, 0, link_gbs=50.0, vmem_mb=128, async_engine="dma"),
    Chip("TPUv5p", "google", "2023", "TPUv5p", "tpu", 95, 2765.0, 459.0, 0.0, 2, 0, 0, link_gbs=100.0, vmem_mb=128, async_engine="dma"),
)

CATALOG: Dict[str, Chip] = {c.name: c for c in GPUS + HOPPER + TPUS}

#: the datacenter arc the lineage analysis walks (paper Table 1 order,
#: extended into Hopper).  H200 rides the same GH100 die at equal peak FLOPs
#: (only bandwidth moves), so it is validated as an A100/H100 pair in
#: ``repro.bench.lineage`` rather than a lineage step.
DATACENTER_LINEAGE: Tuple[str, ...] = (
    "K80", "P100", "V100", "A100", "H100-SXM")


# --- the framework's target chip ---------------------------------------------
# All roofline terms in launch/dryrun.py + benchmarks use these constants
# (given in the assignment): TPU v5e.

TARGET = CATALOG["TPUv5e"]
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip per-direction)
VMEM_BYTES = 128 * 2 ** 20   # ~128 MiB VMEM per chip
HBM_BYTES = 16 * 2 ** 30     # 16 GiB per chip


def get_chip(name: str) -> Chip:
    try:
        return CATALOG[name]
    except KeyError:
        raise KeyError(f"unknown chip {name!r}; known: {sorted(CATALOG)}") from None
