"""Hardware catalog.

Reproduces the paper's Table 1 (eight Nvidia GPUs across five generations)
verbatim, and extends the lineage with the TPU generations this framework
targets — the machine-balance analysis (paper Fig. 1) and the expected-speedup
model (paper §6) are computed over these records.

All numbers are peak/vendor figures, matching the paper's methodology
(techpowerup / vendor datasheets).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class Chip:
    name: str
    vendor: str
    year: str
    arch: str
    grade: str                     # "datacenter" | "consumer" | "tpu"
    mem_gb: float
    mem_bw_gbs: float              # external memory bandwidth, GB/s
    tflops_f32: float              # fp32 (GPU) / bf16 (TPU — the lineage metric)
    tflops_f64: float
    n_cores: int                   # SMs (GPU) / TensorCores-per-chip (TPU)
    tdp_w: float
    die_mm2: float
    # interconnect (per-link, unidirectional)
    link_gbs: float = 0.0
    vmem_mb: float = 0.0           # on-chip scratch (shared mem / VMEM)


# --- paper Table 1, verbatim -------------------------------------------------

GPUS: Tuple[Chip, ...] = (
    # Tesla / data-center
    Chip("K80", "nvidia", "2014Q4", "Kepler", "datacenter", 12, 240.6, 4.113, 1.371, 13, 300, 561),
    Chip("P100", "nvidia", "2016Q2", "Pascal", "datacenter", 16, 732.2, 10.61, 5.304, 56, 300, 610),
    Chip("V100", "nvidia", "2017Q3", "Volta", "datacenter", 16, 897.0, 14.13, 7.066, 80, 300, 815),
    Chip("A100", "nvidia", "2020Q3", "Ampere", "datacenter", 40, 1555.0, 19.49, 9.746, 108, 250, 826),
    # Workstation / consumer
    Chip("GTX745", "nvidia", "2014Q1", "Maxwell", "consumer", 4, 28.80, 0.793, 0.02479, 3, 55, 148),
    Chip("K2200", "nvidia", "2014Q3", "Maxwell", "consumer", 4, 80.19, 1.439, 0.04496, 5, 68, 148),
    Chip("GTX1050Ti", "nvidia", "2016Q4", "Pascal", "consumer", 4, 112.1, 2.138, 0.0668, 6, 75, 132),
    Chip("RTX2060S", "nvidia", "2019Q3", "Turing", "consumer", 8, 448.0, 7.181, 0.224, 34, 175, 445),
)

# --- TPU lineage extension ---------------------------------------------------
# tflops_f32 column holds bf16/matmul peak for TPUs (the throughput metric the
# lineage comparison uses); f64 is N/A on TPU (0.0).

TPUS: Tuple[Chip, ...] = (
    Chip("TPUv2", "google", "2017", "TPUv2", "tpu", 8, 700.0, 45.0, 0.0, 2, 280, 0, link_gbs=62.5, vmem_mb=24),
    Chip("TPUv3", "google", "2018", "TPUv3", "tpu", 16, 900.0, 123.0, 0.0, 2, 220, 0, link_gbs=81.25, vmem_mb=32),
    Chip("TPUv4", "google", "2021", "TPUv4", "tpu", 32, 1200.0, 275.0, 0.0, 2, 170, 0, link_gbs=50.0, vmem_mb=128),
    Chip("TPUv5e", "google", "2023", "TPUv5e", "tpu", 16, 819.0, 197.0, 0.0, 1, 0, 0, link_gbs=50.0, vmem_mb=128),
    Chip("TPUv5p", "google", "2023", "TPUv5p", "tpu", 95, 2765.0, 459.0, 0.0, 2, 0, 0, link_gbs=100.0, vmem_mb=128),
)

CATALOG: Dict[str, Chip] = {c.name: c for c in GPUS + TPUS}


# --- the framework's target chip ---------------------------------------------
# All roofline terms in launch/dryrun.py + benchmarks use these constants
# (given in the assignment): TPU v5e.

TARGET = CATALOG["TPUv5e"]
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip per-direction)
VMEM_BYTES = 128 * 2 ** 20   # ~128 MiB VMEM per chip
HBM_BYTES = 16 * 2 ** 30     # 16 GiB per chip


def get_chip(name: str) -> Chip:
    try:
        return CATALOG[name]
    except KeyError:
        raise KeyError(f"unknown chip {name!r}; known: {sorted(CATALOG)}") from None
