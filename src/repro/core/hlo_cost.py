"""HLO-text cost analysis that is *loop-aware*.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which silently
undercounts scan-over-layers models by a factor of n_layers (validated in
tests/test_roofline.py).  This module parses the compiled HLO text and walks
the computation graph from ENTRY, multiplying while bodies by their
``known_trip_count`` backend config, so the roofline terms are correct for
scanned programs.  It also attributes collective wire bytes inside loops
(a per-layer all-reduce in a 95-layer scan is 95 all-reduces, not 1).

Cost model:
  flops   dot = 2 * |out| * contracted;  float elementwise = |out|;
          reduce/reduce-window = |in|;  conditional = max(branches)
  bytes   post-fusion HBM model: every top-level op moves its operands +
          output once; fusions count only their boundary; free ops
          (parameter, tuple, gte, bitcast, constant, reshape) move nothing.
  wire    ring-algorithm collective bytes (see core.roofline)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .roofline import DTYPE_BYTES, _ring_wire_bytes

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FLOP1 = {  # 1 flop per output element
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "cosine", "sine", "logistic",
    "atan2", "cbrt", "erf", "floor", "ceil", "round-nearest-afz",
    "remainder",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "rng-bit-generator", "rng-get-and-update-state", "opt-barrier",
    "custom-call", "get-dimension-size",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every shape literal in ``text``."""
    elems = tot = 0
    for dtype, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        nb = DTYPE_BYTES.get(dtype, 0)
        if nb:
            elems += n
            tot += n * nb
    return elems, tot


@dataclass
class Cost:
    """``bytes`` is the CPU-granularity upper bound (every top-level op moves
    its operands); ``bytes_fused`` assumes a TPU-grade fusing compiler where
    elementwise/convert/select chains ride along with their consumers —
    the memory roofline term uses ``bytes_fused`` and reports both."""
    flops: float = 0.0
    bytes: float = 0.0
    bytes_fused: float = 0.0
    wire_bytes: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_fused += o.bytes_fused
        self.wire_bytes += o.wire_bytes
        for k, v in o.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        for k, v in o.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t, self.bytes_fused * t,
                    self.wire_bytes * t,
                    {k: int(v * t) for k, v in self.collective_counts.items()},
                    {k: v * t for k, v in self.collective_bytes.items()})


@dataclass
class _Op:
    opcode: str
    line: str
    out_elems: int
    out_bytes: int
    in_elems: int
    in_bytes: int
    lhs_dims: Optional[List[int]] = None    # first-operand dims (for dot)
    arg_bytes: Optional[List[int]] = None   # per-operand bytes
    arg_names: Optional[List[str]] = None   # per-operand value names


_NAME = re.compile(r"%([\w\.\-]+)")


def _split_args(s: str) -> List[str]:
    """Split an HLO operand list on top-level commas."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[_Op]], Optional[str]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    # symbol table: value name -> (elems, bytes, dims-of-first-shape)
    sym: Dict[str, Tuple[int, int, List[int]]] = {}
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _COMP_START.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            sym = {}
            if m.group(1):
                entry = cur
            # computation parameters appear in the signature:  (p: f32[2,3])
            sig = m.group(3)
            for part in _split_args(sig):
                if ":" in part:
                    pname, ptype = part.split(":", 1)
                    e, b = _shape_elems_bytes(ptype)
                    dims = _first_dims(ptype)
                    sym[pname.strip().lstrip("%")] = (e, b, dims)
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None or " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        lhs_name = lhs.strip().lstrip("%")
        if lhs_name.startswith("ROOT "):
            lhs_name = lhs_name[5:].lstrip("%")
        if lhs.strip().startswith("ROOT"):
            lhs_name = lhs.strip().split()[-1].lstrip("%")
        rhs2 = rhs.strip()
        # the output type may be a tuple "(s32[], f32[2,3])" — skip it first
        if rhs2.startswith("("):
            depth = 0
            tend = len(rhs2)
            for i, ch in enumerate(rhs2):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        tend = i + 1
                        break
            head = rhs2[:tend]
            rest = rhs2[tend:].lstrip()
        else:
            parts = rhs2.split(None, 1)
            head = parts[0]
            rest = parts[1] if len(parts) > 1 else ""
        paren = rest.find("(")
        if paren < 0:
            continue
        opcode = rest[:paren].strip()
        out_e, out_b = _shape_elems_bytes(head)
        sym[lhs_name] = (out_e, out_b, _first_dims(head))
        # strip async wrappers: count "-start", skip "-done"/"-update"
        if opcode.endswith("-done") or opcode.endswith("-update"):
            continue
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        # operand region: top-level parens only
        depth, end = 0, len(rest)
        for i, ch in enumerate(rest[paren:], paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = _split_args(rest[paren + 1:end])
        in_e = in_b = 0
        lhs_dims: Optional[List[int]] = None
        arg_bytes: List[int] = []
        arg_names: List[str] = []
        for i, a in enumerate(args):
            nm = _NAME.search(a)
            if _SHAPE.search(a):
                e, b = _shape_elems_bytes(a)
                dims = _first_dims(a)
            else:
                e, b, dims = sym.get(nm.group(1), (0, 0, [])) if nm \
                    else (0, 0, [])
            in_e += e
            in_b += b
            arg_bytes.append(b)
            arg_names.append(nm.group(1) if nm else "")
            if i == 0:
                lhs_dims = dims
        comps[cur].append(_Op(base, line, out_e, out_b, in_e, in_b,
                              lhs_dims, arg_bytes, arg_names))
    return comps, entry


def _first_dims(text: str) -> List[int]:
    m = _SHAPE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


def _dot_flops(op: _Op) -> float:
    m = _CONTRACT.search(op.line)
    lhs_dims = op.lhs_dims or []
    contracted = 1
    if m and m.group(1).strip() and lhs_dims:
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    return 2.0 * op.out_elems * contracted


def _group_size(line: str) -> int:
    m = _GROUPS.search(line)
    if m:
        return len([g for g in m.group(1).split(",") if g.strip()])
    m2 = _GROUPS_IOTA.search(line)
    return int(m2.group(2)) if m2 else 1


# loop-invariant operands up to this size are assumed VMEM-resident across
# loop iterations (the TPU would hoist them); larger ones stream per trip
VMEM_RESIDENT_BYTES = 48 * 2 ** 20


def _body_invariants(ops: List[_Op]) -> Dict[str, int]:
    """gte-name -> bytes for loop-carried tuple slots that pass through the
    body unchanged (root tuple operand k is the gte of index k)."""
    gtes: Dict[str, Tuple[int, int]] = {}       # name -> (index, bytes)
    root: Optional[_Op] = None
    for op in ops:
        if op.opcode == "get-tuple-element":
            mi = re.search(r"index=(\d+)", op.line)
            nm = re.search(r"%([\w\.\-]+)\s*=", op.line)
            if mi and nm:
                gtes[nm.group(1)] = (int(mi.group(1)), op.out_bytes)
        if op.opcode == "tuple" and "ROOT" in op.line:
            root = op
    if root is None or not root.arg_names:
        return {}
    inv: Dict[str, int] = {}
    for pos, nm in enumerate(root.arg_names):
        if nm in gtes and gtes[nm][0] == pos:
            inv[nm] = gtes[nm][1]
    return inv


def analyze_hlo(hlo: str) -> Cost:
    comps, entry = _parse_computations(hlo)
    if entry is None:
        # single-computation fallback
        entry = next(iter(comps)) if comps else None
        if entry is None:
            return Cost()
    memo: Dict[Tuple[str, frozenset], Cost] = {}

    producers: Dict[str, Dict[str, _Op]] = {}

    def _producer_map(name: str) -> Dict[str, _Op]:
        if name not in producers:
            m: Dict[str, _Op] = {}
            for op in comps.get(name, []):
                nm = re.search(r"%([\w\.\-]+)\s*=", op.line)
                if nm:
                    m[nm.group(1)] = op
            producers[name] = m
        return producers[name]

    def comp_cost(name: str, exclude: frozenset = frozenset()) -> Cost:
        key = (name, exclude)
        if key in memo:
            return memo[key]
        memo[key] = Cost()           # break cycles defensively
        total = Cost()
        pmap = _producer_map(name)
        for op in comps.get(name, []):
            total += op_cost(op, exclude, pmap)
        memo[key] = total
        return total

    def _excluded_bytes(op: _Op, exclude: frozenset) -> float:
        if not exclude or not op.arg_names:
            return 0.0
        return float(sum(b for n, b in zip(op.arg_names, op.arg_bytes or [])
                         if n in exclude))

    def op_cost(op: _Op, exclude: frozenset = frozenset(),
                pmap: Optional[Dict[str, _Op]] = None) -> Cost:
        c = Cost()
        oc = op.opcode
        if oc == "while":
            body = _BODY.search(op.line)
            cond = _COND.search(op.line)
            trips = 1
            mt = _TRIP.search(op.line)
            if mt:
                trips = int(mt.group(1))
            inner = Cost()
            once = 0.0
            if body:
                bname = body.group(1)
                inv = {n: b for n, b in
                       _body_invariants(comps.get(bname, [])).items()
                       if 0 < b <= VMEM_RESIDENT_BYTES}
                inner += comp_cost(bname, frozenset(inv))
                once = float(sum(set(inv.values())) if False
                             else sum(inv.values()))
            if cond:
                inner += comp_cost(cond.group(1))
            total = inner.scaled(trips)
            # invariant small operands stream to VMEM once, not per trip
            total.bytes += once
            total.bytes_fused += once
            return total
        if oc == "conditional":
            mb = _BRANCHES.search(op.line)
            if mb:
                branches = [b.strip().lstrip("%") for b in
                            mb.group(1).split(",") if b.strip()]
                costs = [comp_cost(b) for b in branches]
                if costs:
                    best = max(costs, key=lambda x: x.flops + x.bytes)
                    c += best
            c.bytes += op.in_bytes + op.out_bytes
            return c
        if oc == "fusion":
            mcall = _CALLS.search(op.line)
            inner_bytes = float(op.in_bytes)
            inner_fused = float(op.in_bytes)
            if mcall:
                inner = comp_cost(mcall.group(1))
                c.flops += inner.flops          # flops inside the fusion
                c.wire_bytes += inner.wire_bytes
                for k, v in inner.collective_counts.items():
                    c.collective_counts[k] = v
                for k, v in inner.collective_bytes.items():
                    c.collective_bytes[k] = v
                inner_bytes = inner.bytes
                inner_fused = inner.bytes_fused
            # boundary traffic, but a fusion that only windows into a big
            # operand/output (dynamic-slice / dynamic-update-slice of the
            # stacked scan buffers) moves the window, not the buffer: take
            # the smaller of boundary and inner-walk models.
            boundary = float(op.in_bytes + op.out_bytes) \
                - _excluded_bytes(op, exclude)
            # a fusion node IS the fused unit: its boundary is what a TPU
            # fusion moves; the inner walk only catches slice/DUS windows
            b = min(boundary, inner_bytes)
            c.bytes += b
            c.bytes_fused += b
            return c
        if oc == "call":
            mcall = _CALLS.search(op.line) or re.search(
                r"to_apply=%?([\w\.\-]+)", op.line)
            if mcall:
                c += comp_cost(mcall.group(1))
            return c
        if oc in _COLLECTIVES:
            n = _group_size(op.line)
            in_b = float(op.in_bytes if op.in_bytes else op.out_bytes)
            # bf16-emulation correction: the CPU backend upcasts bf16 values
            # to f32 around dots, so collectives of "converted" operands are
            # printed at twice the width a TPU program would move.  When the
            # producing op is a pure upcast (input bytes == output/2), charge
            # the collective at the source width.
            if pmap and op.arg_names:
                shrink = True
                for a in op.arg_names:
                    prod = pmap.get(a)
                    if prod is None or prod.opcode not in (
                            "convert", "fusion", "copy"):
                        shrink = False
                        break
                    if not (prod.arg_bytes and any(
                            b2 * 2 == prod.out_bytes      # pure upcast
                            or b2 == 2 * prod.out_bytes   # slice of bf16 full
                            for b2 in prod.arg_bytes if b2)):
                        shrink = False
                        break
                if shrink:
                    in_b *= 0.5
            wire = _ring_wire_bytes(oc, in_b, op.out_bytes, n)
            c.wire_bytes += wire
            c.collective_counts[oc] = 1
            c.collective_bytes[oc] = wire
            c.bytes += op.in_bytes + op.out_bytes
            c.bytes_fused += op.in_bytes + op.out_bytes
            return c
        if oc in _FREE:
            if oc == "custom-call":
                c.bytes += op.in_bytes + op.out_bytes
            return c
        # ordinary op
        skip = _excluded_bytes(op, exclude)
        if oc == "dot":
            c.flops += _dot_flops(op)
        elif oc == "convolution":
            c.flops += 2.0 * op.out_elems  # no convs in these models
        elif oc in _FLOP1 or oc in ("select", "compare", "clamp", "and",
                                    "or", "not", "xor"):
            if oc in _FLOP1:
                c.flops += op.out_elems
        elif oc in ("reduce", "reduce-window", "sort", "scatter"):
            c.flops += op.in_elems
        # HBM traffic: slicing/windowed ops touch only the window, not the
        # whole operand (a scan reading per-layer slices of stacked params
        # would otherwise be charged L x full-stack bytes).
        fusable = oc in _FLOP1 or oc in ("select", "compare", "clamp",
                                         "and", "or", "not", "xor",
                                         "convert", "copy", "transpose",
                                         "broadcast", "reverse", "pad")
        if oc in ("dynamic-slice", "slice", "gather"):
            b = 2.0 * op.out_bytes + (
                sum(op.arg_bytes[1:]) if op.arg_bytes else 0)
            c.bytes += b
            c.bytes_fused += b
        elif oc == "dynamic-update-slice":
            upd = op.arg_bytes[1] if op.arg_bytes and len(op.arg_bytes) > 1 \
                else op.out_bytes
            c.bytes += 2.0 * upd
            c.bytes_fused += 2.0 * upd
        elif oc == "scatter":
            upd = op.arg_bytes[2] if op.arg_bytes and len(op.arg_bytes) > 2 \
                else op.out_bytes
            idx = op.arg_bytes[1] if op.arg_bytes and len(op.arg_bytes) > 1 \
                else 0
            c.bytes += 2.0 * upd + idx
            c.bytes_fused += 2.0 * upd + idx
        elif oc == "broadcast":
            c.bytes += op.out_bytes
        elif fusable:
            # upper bound: materialised; fused model: rides with consumer
            c.bytes += max(op.in_bytes - skip, 0) + op.out_bytes
        else:
            b = max(op.in_bytes - skip, 0) + op.out_bytes
            c.bytes += b
            c.bytes_fused += b
        return c

    return comp_cost(entry)


def cost_with_loops(compiled) -> Cost:
    """Loop-aware cost of a compiled executable (per device, SPMD)."""
    return analyze_hlo(compiled.as_text())


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalised across jax versions: older jax
    returns a one-element list of per-device dicts, newer jax the dict
    itself.  Always returns the (single-program) dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


# ---------------------------------------------------------------------------
# Profiling: weighted top ops (the dry-run "profile" — there is no wall-clock
# trace on this host, so §Perf iterations read this instead)
# ---------------------------------------------------------------------------

def top_costs(hlo: str, k: int = 15):
    """Top-k ops by trip-weighted fused bytes and by flops.  Returns
    (by_bytes, by_flops, by_wire) lists of (weighted_value, weight, line)."""
    comps, entry = _parse_computations(hlo)
    weights = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        w = weights[name]
        for op in comps.get(name, []):
            trips = 1
            if op.opcode == "while":
                mt = _TRIP.search(op.line)
                trips = int(mt.group(1)) if mt else 1
            for regex in (_BODY, _COND, _CALLS):
                m = regex.search(op.line)
                if m:
                    child = m.group(1)
                    if child not in weights:
                        weights[child] = 0.0
                        order.append(child)
                    weights[child] += w * trips

    memo_b: Dict[str, float] = {}

    def comp_bytes(name):
        if name in memo_b:
            return memo_b[name]
        memo_b[name] = 0.0
        t = sum(op_bytes(op)[0] for op in comps.get(name, []))
        memo_b[name] = t
        return t

    FUSABLE = _FLOP1 | {"select", "compare", "clamp", "and", "or", "not",
                        "xor", "convert", "copy", "transpose", "broadcast",
                        "reverse", "pad"}

    def op_bytes(op):
        oc = op.opcode
        if oc == "while":
            return 0.0, True        # charged via child weights
        if oc == "fusion":
            m = _CALLS.search(op.line)
            inner = comp_bytes(m.group(1)) if m else 1e30
            return min(float(op.in_bytes + op.out_bytes), inner), False
        if oc in _FREE or oc in FUSABLE:
            return 0.0, False
        if oc in ("dynamic-slice", "slice", "gather"):
            return 2.0 * op.out_bytes, False
        if oc == "dynamic-update-slice":
            return 2.0 * (op.arg_bytes[1] if op.arg_bytes
                          and len(op.arg_bytes) > 1 else op.out_bytes), False
        return float(op.in_bytes + op.out_bytes), False

    by_bytes, by_flops, by_wire = [], [], []
    for name, ops in comps.items():
        w = weights.get(name, 0.0)
        if not w:
            continue
        for op in ops:
            if op.opcode == "while":
                continue
            b, skip = op_bytes(op)
            if b:
                by_bytes.append((w * b, w, op.line.strip()[:140]))
            if op.opcode == "dot":
                f = _dot_flops(op)
                if f:
                    by_flops.append((w * f, w, op.line.strip()[:140]))
            if op.opcode in _COLLECTIVES:
                n = _group_size(op.line)
                in_b = op.in_bytes or op.out_bytes
                wire = _ring_wire_bytes(op.opcode, in_b, op.out_bytes, n)
                if wire:
                    by_wire.append((w * wire, w, op.line.strip()[:140]))
    for lst in (by_bytes, by_flops, by_wire):
        lst.sort(key=lambda t: -t[0])
    return by_bytes[:k], by_flops[:k], by_wire[:k]
