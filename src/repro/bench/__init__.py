"""Unified measurement & scenario subsystem.

The paper's method is disciplined cross-generation measurement: identical
workloads, one timing protocol, results with enough provenance to replay
the analysis.  This package is that spine for the whole repo:

  timing        the one warmup/repeat/IQR-outlier timer (the autotuner and
                every benchmark import it; nothing else times anything)
  scenario      declarative Scenario registry — kernel x shape x dtype x
                Strategy — covering every paper figure and user workloads
  results       schema-versioned BenchResult/BenchReport (BENCH_*.json)
  runner        run/sweep: resolve config (tuning registry aware), check
                against the ref oracle, measure, project across the chip
                lineage
  regime        fold regime/* depth sweeps into per-cell "async pays /
                async hurts" verdict rows (kind="regime")
  cli           python -m repro.bench.cli {list,run,sweep}

Import note: ``timing``/``results``/``scenario`` are imported eagerly (and
in that order — ``tuning.autotuner`` imports ``repro.bench.timing`` while
this package may itself be mid-import via ``tuning.search_space``);
``runner``/``cli`` are plain submodules, import them directly.
"""
from . import timing                                        # noqa: F401
from .timing import TimingStats, reject_outliers, time_callable
from . import results                                       # noqa: F401
from .results import (SCHEMA_VERSION, BenchReport, BenchResult,
                      ResultSchemaMismatch)
from . import scenario                                      # noqa: F401
from .scenario import Scenario, get_scenario, register, scenarios
from . import regime                                        # noqa: F401
from .regime import PAYS_MARGIN, regime_rows

__all__ = [
    "BenchReport", "BenchResult", "PAYS_MARGIN", "ResultSchemaMismatch",
    "SCHEMA_VERSION", "Scenario", "TimingStats", "get_scenario", "regime",
    "regime_rows", "register", "reject_outliers", "results", "scenario",
    "scenarios", "time_callable", "timing",
]
