"""Benchmark command line.

    PYTHONPATH=src python -m repro.bench.cli list [--tag fig4]
    PYTHONPATH=src python -m repro.bench.cli run --only fig3 --json out.json
    PYTHONPATH=src python -m repro.bench.cli sweep --smoke --json BENCH.json

``list`` prints registered scenarios without running anything.  ``run``
measures the selected scenarios on this host.  ``sweep`` measures them AND
projects each through the roofline model across the chip lineage (every
``core.hardware`` Chip, or ``--chip`` to restrict).  ``--json -`` writes
the schema-v2 report to stdout and keeps all progress on stderr, so the
output is machine-parseable.
"""
from __future__ import annotations

import argparse
import logging
import os
import sys
from typing import List, Optional

from ..core import hardware
from ..core.async_pipeline import Strategy, parse_strategy
from ..tuning.registry import Registry
from . import lineage, runner, scenario
from .results import BenchReport


def _strategy(text: Optional[str]) -> Optional[Strategy]:
    if not text:
        return None
    try:
        return parse_strategy(text)
    except ValueError as e:
        raise SystemExit(f"error: {e}")


def _filters(args) -> dict:
    return dict(only=args.only, kernel=args.kernel,
                strategy=_strategy(args.strategy), tag=args.tag,
                smoke=True if getattr(args, "smoke", False) else None)


def _select(args) -> List[scenario.Scenario]:
    scs = scenario.scenarios(**_filters(args))
    if not scs:
        print("error: no scenarios match the given filters",
              file=sys.stderr)
        raise SystemExit(2)
    return scs


def _progress_stream(args):
    return sys.stderr if args.json == "-" else sys.stdout


def _emit(stream):
    def emit(r):
        m = r.metrics
        if r.kind == "regime":          # derived verdict row, not a timing
            be = m.get("break_even_depth")
            val = (f"verdict={m['verdict']} "
                   f"break_even_depth={be if be is not None else '-'} "
                   f"speedup={m['speedup']:.2f}x")
        elif "us_median" in m:
            val = f"us_median={m['us_median']:.1f}"
        else:
            val = f"predicted_us={m['predicted_us']:.2f}"
        extra = ""
        if "max_err" in m:
            extra = f" max_err={m['max_err']:.2e}" + \
                ("" if m.get("check_ok", True) else " CHECK-FAILED")
        print(f"{r.kind:<9s}{r.scenario:<36s} chip={r.chip:<10s} "
              f"strategy={r.strategy:<16s} {val}{extra}",
              file=stream, flush=True)
    return emit


def _options(args, stream) -> runner.RunOptions:
    return runner.RunOptions(
        warmup=args.warmup, repeats=args.repeats,
        interpret=not args.compiled, check=not args.no_check,
        use_tuned=not args.no_tuned, chip=getattr(args, "chip", None),
        registry=Registry(args.registry) if args.registry else None,
        emit=_emit(stream))


def _write_json(report: BenchReport, args, stream) -> None:
    if not args.json:
        return
    if args.json == "-":
        report.save(sys.stdout)
    else:
        report.save(args.json)
        print(f"# wrote {len(report)} rows to {args.json}", file=stream)


def _start_trace(args):
    """Enable the obs tracer when a trace output was requested."""
    if getattr(args, "trace", None) or getattr(args, "chrome_trace", None):
        from ..obs.trace import tracer
        t = tracer()
        t.clear()
        t.enable()
        return t
    return None


def _write_trace(t, args, stream) -> None:
    if t is None:
        return
    import json as _json
    if args.trace:
        n = t.save_jsonl(args.trace)
        print(f"# wrote {n} spans to {args.trace}", file=stream)
    if args.chrome_trace:
        doc = t.chrome_trace()
        with open(args.chrome_trace, "w") as f:
            _json.dump(doc, f)
        print(f"# wrote {len(doc['traceEvents'])} trace events to "
              f"{args.chrome_trace} (load in https://ui.perfetto.dev)",
              file=stream)


def cmd_list(args) -> int:
    scs = scenario.scenarios(**_filters(args))
    if not scs:
        print("no scenarios match the given filters", file=sys.stderr)
        return 2
    print(f"{'name':<36s} {'kernel':<16s} {'shape':<14s} {'strategy':<16s} "
          f"{'tags':<14s} smoke")
    for sc in scs:
        strat = sc.strategy.value if sc.strategy else "(default)"
        print(f"{sc.name:<36s} {sc.kernel:<16s} "
              f"{'x'.join(map(str, sc.shape)):<14s} {strat:<16s} "
              f"{','.join(sc.tags):<14s} {'y' if sc.smoke else 'n'}")
    print(f"# {len(scs)} scenarios")
    return 0


def cmd_run(args) -> int:
    stream = _progress_stream(args)
    scs = _select(args)
    opts = _options(args, stream)
    t = _start_trace(args)
    report = runner.run_scenarios(scs, opts)
    bad = [r for r in report.results
           if r.metrics.get("check_ok") is False]
    _write_json(report, args, stream)
    _write_trace(t, args, stream)
    if bad:
        print(f"error: {len(bad)} scenario(s) failed the oracle check: "
              f"{[r.scenario for r in bad]}", file=sys.stderr)
        return 1
    return 0


def cmd_sweep(args) -> int:
    stream = _progress_stream(args)
    if args.smoke and not (args.only or args.kernel or args.strategy
                           or args.tag):
        scs = scenario.scenarios(smoke=True)
    else:
        scs = _select(args)
    chips = args.chip or list(hardware.CATALOG)
    opts = _options(args, stream)
    # --chip restricts the model projection, not the host's provenance chip
    opts.chip = None
    t = _start_trace(args)
    report = runner.sweep(scs, chips, opts)
    measured = sum(1 for r in report.results if r.kind == "measured")
    regime = [r for r in report.results if r.kind == "regime"]
    print(f"# sweep: {measured} measured rows + "
          f"{len(report) - measured - len(regime)} model rows over "
          f"{len(chips)} chips + {len(regime)} regime verdicts",
          file=stream)
    for r in regime:
        be = r.metrics.get("break_even_depth")
        print(f"#   regime {r.kernel:<16s} "
              f"{'x'.join(map(str, r.shape)):<14s} "
              f"{r.metrics['verdict']:<8s} "
              f"break-even depth={be if be is not None else '-'} "
              f"best=d{r.metrics['best_depth']} "
              f"({r.metrics['speedup']:.2f}x vs sync)", file=stream)
    _write_json(report, args, stream)
    _write_trace(t, args, stream)
    return 0


def cmd_lineage(args) -> int:
    """Validate catalog speedup expectations against the committed
    published-number reference table; nonzero on any over/under verdict."""
    import json as _json
    stream = _progress_stream(args)
    try:
        pairs = lineage.load_reference(args.reference)
    except (OSError, ValueError, KeyError,
            _json.JSONDecodeError) as e:
        print(f"error: cannot load reference {args.reference}: {e}",
              file=sys.stderr)
        return 2
    verdicts = lineage.validate(pairs)
    chain = lineage.lineage_chain(precision=args.precision)
    print(f"# lineage arc ({args.precision}): " + " -> ".join(
        hardware.DATACENTER_LINEAGE), file=stream)
    for v in chain:
        print(f"chain    {v.old:>9s} -> {v.new:<10s} "
              f"expected={v.expected:5.2f}x "
              f"(flops {v.flop_ratio:.2f}x, bw {v.bw_ratio:.2f}x; "
              f"{v.binds} bind)", file=stream)
    for v in verdicts:
        print(f"{v.verdict:<12s} {v.old:>9s} -> {v.new:<10s} "
              f"[{v.precision}] expected={v.expected:5.2f}x "
              f"published={v.published:5.2f}x "
              f"dev={v.rel_dev:+.1%} band=+-{v.band:.0%}", file=stream)
    doc = lineage.to_doc(verdicts, chain,
                         reference=os.path.basename(args.reference))
    if args.json:
        if args.json == "-":
            _json.dump(doc, sys.stdout, indent=1, sort_keys=True)
            sys.stdout.write("\n")
        else:
            with open(args.json, "w") as f:
                _json.dump(doc, f, indent=1, sort_keys=True)
            print(f"# wrote {len(verdicts)} verdicts to {args.json}",
                  file=stream)
    c = doc["counts"]
    print(f"# lineage: {c.get('within-band', 0)} within-band, "
          f"{c.get('over', 0)} over, {c.get('under', 0)} under",
          file=stream)
    if not doc["ok"]:
        bad = [f"{v.old}->{v.new}[{v.precision}]" for v in verdicts
               if not v.ok]
        print(f"error: catalog expectations drifted outside the published "
              f"band: {bad}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench.cli",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("-v", "--verbose", action="store_true")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def add_filters(p):
        p.add_argument("--only", default=None,
                       help="substring filter over scenario names; "
                            "comma-separates alternatives (OR)")
        p.add_argument("--kernel", choices=scenario.KERNELS, default=None)
        p.add_argument("--strategy", default=None,
                       help="async strategy filter "
                            f"({[s.value for s in Strategy]})")
        p.add_argument("--tag", default=None,
                       help="scenario tag filter "
                            "(smoke/fig3/fig4/paper/regime/serve)")
        p.add_argument("--smoke", action="store_true",
                       help="only smoke-tagged scenarios")

    def add_measure(p):
        p.add_argument("--repeats", type=int, default=5)
        p.add_argument("--warmup", type=int, default=1)
        p.add_argument("--no-check", action="store_true",
                       help="skip the ref-oracle correctness check")
        p.add_argument("--no-tuned", action="store_true",
                       help="ignore the tuning registry; seed defaults only")
        p.add_argument("--compiled", action="store_true",
                       help="compile for the real backend instead of the "
                            "CPU Pallas interpreter (use on TPU)")
        p.add_argument("--registry", default=None,
                       help="tuning registry JSON to resolve configs from")
        p.add_argument("--json", default=None, metavar="PATH",
                       help="write the schema-v2 report ('-' for stdout; "
                            "progress then goes to stderr)")
        p.add_argument("--trace", default=None, metavar="PATH",
                       help="enable span tracing and write the span JSONL "
                            "(repro.obs) to PATH")
        p.add_argument("--chrome-trace", default=None, metavar="PATH",
                       help="enable span tracing and write a Perfetto/"
                            "chrome://tracing JSON to PATH")

    p = sub.add_parser("list", help="print registered scenarios (no run)")
    add_filters(p)
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("run", help="measure scenarios on this host")
    add_filters(p)
    add_measure(p)
    p.add_argument("--chip", default=None, choices=sorted(hardware.CATALOG),
                   help="provenance/tuning-lookup chip (default: TARGET)")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("sweep",
                       help="measure + roofline-project across the lineage")
    add_filters(p)
    add_measure(p)
    p.add_argument("--chip", action="append", default=None,
                   choices=sorted(hardware.CATALOG), metavar="CHIP",
                   help="restrict the projection (repeatable; default: "
                        "every registered chip)")
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("lineage",
                       help="validate catalog speedup expectations against "
                            "the committed published-number reference")
    p.add_argument("--reference", default=lineage.default_reference_path(),
                   metavar="PATH",
                   help="lineage-reference JSON "
                        "(default: experiments/baselines/"
                        "LINEAGE_hopper.json)")
    p.add_argument("--precision", default="f32", choices=("f32", "f64"),
                   help="precision for the lineage-arc chain rows "
                        "(reference pairs carry their own)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the lineage-validation verdict document "
                        "('-' for stdout; progress then goes to stderr)")
    p.set_defaults(fn=cmd_lineage)

    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO if args.verbose
                        else logging.WARNING)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
