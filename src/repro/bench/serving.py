"""Measure end-to-end serving scenarios (``serve/*``) into schema-v2 rows.

A serving row is a ``BenchResult`` like any kernel cell, so the whole
existing toolchain — BENCH_*.json artifacts, ``obs.cli compare`` noise
gating, ``experiments/make_report.py`` — applies unchanged:

  us_median / times_us   median / raw per-step decode latency in µs (the
                         gated quantity: the compare gate keys on
                         ``us_median`` and derives noise from the
                         ``times_us`` IQR)
  tokens_per_s           emitted tokens / measured wall time
  ttft_ms_p50 / p99      time-to-first-token percentiles
  decode_ms_p50 / p99    per-step decode latency percentiles (ms)
  occupancy_mean         mean active-slots / batch over decode steps
  requests / tokens      totals for the measured run

Measurement protocol: build the model once, replay the trace once as
warmup (compiling every prefill bucket and the decode step), then replay
it again on fresh ``Request`` objects with a fresh metrics registry —
the measured run is compile-free, matching ``bench.timing``'s
warmup-then-measure discipline.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..obs import metrics as obs_metrics
from ..obs.trace import get_tracer
from .results import BenchResult, now_iso
from .scenario import ServeScenario

__all__ = ["run_serve_scenario"]


def _trace_requests(w: Dict[str, Any], vocab: int):
    from ..serve import make_trace
    return make_trace(
        w.get("arrival", "uniform"), int(w.get("n_requests", 8)),
        vocab=vocab, rate=float(w.get("rate", 0.5)),
        burst=int(w.get("burst", 4)), seed=int(w.get("seed", 0)),
        prompt_lens=tuple(w.get("prompt_lens", (5, 16))),
        max_new=tuple(w.get("max_new", (4, 8))),
        prefix_len=int(w.get("prefix_len", 0)),
        prefix_group=int(w.get("prefix_group", 0)))


def run_serve_scenario(sc: ServeScenario, opts=None) -> BenchResult:
    """Run one serving scenario (warmup replay + measured replay) and
    return its result row.  ``opts`` is a ``runner.RunOptions`` (only
    ``chip`` and ``emit`` apply; serving always runs compiled-for-host)."""
    from ..configs import get_smoke_config
    from ..distributed.sharding import split_tree
    from ..launch.serve import Request, ServingLoop
    from ..models import build_model

    w = sc.workload
    cfg = get_smoke_config(w.get("arch", "qwen2-1.5b"))
    model = build_model(cfg)
    params, _ = split_tree(model.init(jax.random.PRNGKey(0)))

    from ..serve import next_pow2
    trace = _trace_requests(w, cfg.vocab)
    # monolithic prefill books max(next_pow2(prompt), prompt + max_new)
    # rows per slot, so the per-slot cap must cover the pow2 bucket of the
    # longest prompt (shared-prefix prompts push past the next boundary)
    max_new_hi = max(max(next_pow2(len(r.prompt)),
                         len(r.prompt) + r.max_new) for r in trace)

    def build_loop(prefix_cache, chunk_tokens=None):
        return ServingLoop(
            cfg, params, batch=int(w.get("batch", 2)),
            seed=int(w.get("seed", 0)),
            max_new=max(r.max_new for r in trace),
            scheduler=w.get("scheduler", "continuous"),
            block_len=int(w.get("block_len", 8)),
            max_seq=max_new_hi + int(w.get("block_len", 8)),
            chunk_tokens=(w.get("chunk_tokens") if chunk_tokens is None
                          else chunk_tokens),
            prefix_cache=prefix_cache)

    loop = build_loop(bool(w.get("prefix_cache", False)))

    def replay(requests, lp=None):
        return (lp or loop).run(requests, temperature=0.0)

    def fresh():
        return [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new,
                        arrival=r.arrival) for r in trace]

    cache = getattr(loop.scheduler, "cache", None)
    with get_tracer().span(f"scenario:{sc.name}",
                           scheduler=w.get("scheduler", "continuous"),
                           arrival=w.get("arrival", "uniform"),
                           n_requests=len(trace)) as span:
        with get_tracer().span("serve.warmup"):
            replay(fresh())                 # compiles every shape
        if cache is not None and loop.prefix_cache:
            # forget warmup's retained blocks: the measured replay's hit
            # ratio must reflect a cold start, not a pre-seeded cache
            cache.reset_prefix_cache()
        measured = obs_metrics.Registry()
        loop.scheduler.metrics = measured   # fresh counters for the run
        t0 = time.perf_counter()
        results = replay(fresh())
        wall_s = time.perf_counter() - t0

        snap = {row["name"]: row for row in measured.snapshot()}
        dec = snap.get("serve.decode_ms", {})
        ttft = snap.get("serve.ttft_ms", {})
        occ = snap.get("serve.batch_occupancy", {})
        times_us = [v * 1e3 for v in
                    measured.histogram("serve.decode_ms").samples()]
        n_tokens = sum(len(v) for v in results.values())
        metrics: Dict[str, Any] = {
            "us_median": float(np.median(times_us)) if times_us else 0.0,
            "us_mean": dec.get("mean", 0.0) * 1e3,
            "times_us": times_us,
            "tokens_per_s": n_tokens / wall_s if wall_s > 0 else 0.0,
            "wall_s": wall_s,
            "ttft_ms_p50": ttft.get("p50", 0.0),
            "ttft_ms_p99": ttft.get("p99", 0.0),
            "decode_ms_p50": dec.get("p50", 0.0),
            "decode_ms_p99": dec.get("p99", 0.0),
            "occupancy_mean": occ.get("mean", 0.0),
            "requests": snap.get("serve.requests_total", {}).get("value", 0),
            "tokens": n_tokens,
        }
        if loop.chunk_tokens is not None and cache is not None:
            metrics["cache_hit_ratio"] = cache.cache_hit_ratio
            metrics["prefix_hit_tokens"] = cache.hit_tokens
            metrics["prefix_miss_tokens"] = cache.miss_tokens
        if w.get("check_outputs") and loop.prefix_cache:
            # greedy outputs must be bit-identical with sharing disabled:
            # replay the same trace through a fresh non-sharing chunked
            # loop (same chunk settings) and compare token-for-token
            ref_loop = build_loop(False, chunk_tokens=loop.chunk_tokens)
            ref = replay(fresh(), ref_loop)
            equal = (set(ref) == set(results)
                     and all(ref[u] == results[u] for u in ref))
            metrics["outputs_equal"] = bool(equal)
            if not equal:
                diff = [u for u in results
                        if ref.get(u) != results.get(u)]
                raise RuntimeError(
                    f"{sc.name}: prefix sharing changed greedy outputs "
                    f"for requests {diff[:8]}")
        if span is not None:
            span.attrs["us_median"] = metrics["us_median"]
            span.attrs["tokens_per_s"] = metrics["tokens_per_s"]

    from ..core import hardware
    chip = (opts.resolved_chip() if opts is not None
            else hardware.TARGET.name)
    result = BenchResult(
        scenario=sc.name, kernel=sc.kernel, shape=list(sc.shape),
        dtype=cfg.dtype, strategy=loop.scheduler_kind, chip=chip,
        metrics=metrics, config=dict(w), config_source="scenario",
        trace_id=span.span_id if span is not None else None,
        kind="measured", section=sc.section or "serve", interpret=False,
        backend=jax.default_backend(), jax_version=jax.__version__,
        created_at=now_iso())
    if opts is not None and opts.emit:
        opts.emit(result)
    return result
