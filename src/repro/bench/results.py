"""Schema-versioned benchmark result records — the BENCH_*.json format.

Every measured (or roofline-projected) scenario run becomes one
``BenchResult`` row carrying the metrics *and* full provenance: the chip
model (``core.hardware``), the async strategy actually run, the resolved
kernel config and where it came from (tuning registry vs seed default vs
scenario override), backend/interpret mode and jax version.  A
``BenchReport`` is the on-disk trajectory artifact.

Versioning mirrors the tuning registry's discipline: v2 is the current
structured-row format; v1 (the old ``benchmarks/run.py`` free-form
``table/name/metrics`` rows) is *upgraded* on load, never misread, and an
unknown version raises ``ResultSchemaMismatch`` so a future format is never
silently reinterpreted.
"""
from __future__ import annotations

import datetime
import json
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, IO, List, Optional, Union

SCHEMA_VERSION = 2

__all__ = ["SCHEMA_VERSION", "BenchResult", "BenchReport",
           "ResultSchemaMismatch", "upgrade_v1_row", "now_iso"]


class ResultSchemaMismatch(RuntimeError):
    pass


def now_iso() -> str:
    return datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds")


@dataclass
class BenchResult:
    """One result row: what ran, on what, configured how, and the numbers."""
    scenario: str                       # registered scenario name
    kernel: str
    shape: List[int]
    dtype: str
    strategy: str                       # async strategy actually run
    chip: str                           # hardware.Chip model name
    metrics: Dict[str, Any] = field(default_factory=dict)
    # provenance ------------------------------------------------------------
    config: Dict[str, Any] = field(default_factory=dict)   # resolved config
    config_source: str = "default"      # "tuned" | "default" | "scenario" |
    #                                     "legacy-v1"
    tuned_key: Optional[str] = None     # tuning-registry key when tuned
    trace_id: Optional[str] = None      # obs scenario-span id (when traced)
    kind: str = "measured"              # "measured" | "model"
    section: str = ""                   # paper figure/table this row feeds
    interpret: bool = True
    backend: str = ""                   # jax.default_backend() at run time
    jax_version: str = ""
    created_at: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchResult":
        return cls(**d)


def upgrade_v1_row(row: Dict[str, Any]) -> BenchResult:
    """Lift an old ``benchmarks/run.py`` v1 row ({table, name, section,
    metrics}) into a v2 record.  Provenance the old format never carried
    stays empty rather than guessed."""
    return BenchResult(
        scenario=f"{row.get('table', '?')}/{row.get('name', '?')}",
        kernel=str(row.get("table", "")),
        shape=[], dtype="", strategy="", chip="",
        metrics=dict(row.get("metrics", {})),
        config_source="legacy-v1",
        section=str(row.get("section", "")))


@dataclass
class BenchReport:
    """An ordered collection of rows plus run-level provenance; serializes
    to the BENCH_*.json trajectory format."""
    results: List[BenchResult] = field(default_factory=list)
    generator: str = "repro.bench"
    jax_version: str = ""
    backend: str = ""
    created_at: str = ""

    def add(self, result: BenchResult) -> BenchResult:
        self.results.append(result)
        return result

    def extend(self, results) -> None:
        self.results.extend(results)

    def __len__(self) -> int:
        return len(self.results)

    def kernels(self) -> List[str]:
        return sorted({r.kernel for r in self.results if r.kernel})

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": SCHEMA_VERSION,
            "generator": self.generator,
            "jax_version": self.jax_version,
            "backend": self.backend,
            "created_at": self.created_at or now_iso(),
            "rows": [r.to_dict() for r in self.results],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchReport":
        version = d.get("schema_version")
        if version == SCHEMA_VERSION:
            rows = [BenchResult.from_dict(r) for r in d.get("rows", [])]
        elif version == 1:
            rows = [upgrade_v1_row(r) for r in d.get("rows", [])]
        else:
            raise ResultSchemaMismatch(
                f"bench report has schema_version={version!r}, expected "
                f"{SCHEMA_VERSION} (or 1, which is upgraded on load)")
        return cls(results=rows,
                   generator=d.get("generator", "repro.bench"),
                   jax_version=d.get("jax_version", ""),
                   backend=d.get("backend", ""),
                   created_at=d.get("created_at", ""))

    # -- persistence --------------------------------------------------------

    def save(self, out: Union[str, IO[str]]) -> None:
        if hasattr(out, "write"):
            json.dump(self.to_dict(), out, indent=1, sort_keys=True)
            out.write("\n")
        else:
            with open(out, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
                f.write("\n")

    @classmethod
    def load(cls, path: str) -> "BenchReport":
        with open(path) as f:
            return cls.from_dict(json.load(f))
