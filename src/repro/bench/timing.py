"""The canonical measurement primitive for every benchmark and the tuner.

One timing discipline for the whole repo — the paper's methodology (warmup
calls to exclude compilation/tracing, ``repeats`` timed calls, one-sided
IQR outlier rejection before the median is taken) lives here and only here.
``tuning.autotuner`` and every ``repro.bench`` scenario import this module;
no other file may hand-roll a perf_counter loop.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass
from typing import Any, Callable, List

import jax

__all__ = ["TimingStats", "time_callable", "reject_outliers"]


@dataclass
class TimingStats:
    """Per-call wall-clock statistics over the post-rejection samples."""
    times_us: List[float]
    n_outliers: int = 0

    @property
    def median(self) -> float:
        return statistics.median(self.times_us) if self.times_us else 0.0

    @property
    def mean(self) -> float:
        return statistics.fmean(self.times_us) if self.times_us else 0.0

    @property
    def best(self) -> float:
        return min(self.times_us) if self.times_us else 0.0

    @property
    def std(self) -> float:
        return statistics.pstdev(self.times_us) \
            if len(self.times_us) > 1 else 0.0

    def to_metrics(self) -> dict:
        """The flat metric dict every result row carries."""
        return {"us_median": self.median, "us_mean": self.mean,
                "us_min": self.best, "us_std": self.std,
                "n_trials": len(self.times_us),
                "n_outliers": self.n_outliers}


def time_callable(fn: Callable[[], Any], *, warmup: int = 1,
                  repeats: int = 5, outlier_iqr: float = 3.0) -> TimingStats:
    """Wall-time ``fn`` (which must return a jax value to block on).
    ``warmup=0`` is honored: first-call compile cost lands in the timings."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    kept = reject_outliers(times, outlier_iqr)
    return TimingStats(times_us=kept, n_outliers=len(times) - len(kept))


def reject_outliers(times: List[float], k: float) -> List[float]:
    """Drop samples above median + k*IQR (one-sided: slow outliers only —
    preemptions / GC pauses inflate, nothing deflates, a timing)."""
    if len(times) < 4 or k <= 0:
        return list(times)
    s = sorted(times)
    q1 = s[len(s) // 4]
    q3 = s[(3 * len(s)) // 4]
    cut = statistics.median(s) + k * max(q3 - q1, 1e-9)
    kept = [t for t in times if t <= cut]
    return kept or list(times)
