"""The canonical measurement primitive for every benchmark and the tuner.

One timing discipline for the whole repo — the paper's methodology (warmup
calls to exclude compilation/tracing, ``repeats`` timed calls, one-sided
IQR outlier rejection before the median is taken) lives here and only here.
``tuning.autotuner`` and every ``repro.bench`` scenario import this module;
no other file may hand-roll a perf_counter loop.

When ``repro.obs`` tracing is enabled, every trial becomes a span (named
``warmup``/``timed``, outlier-flagged after rejection) nested under
whatever span the caller holds open (the runner's scenario span).  The
spans are recorded *retroactively* from the perf_counter readings the loop
takes anyway — the timed region contains zero tracing code, and the
disabled path is a single attribute check outside the timed window, so
enabling the subsystem costs the measurement nothing.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass
from typing import Any, Callable, List

import jax

from ..obs.trace import get_tracer

__all__ = ["TimingStats", "time_callable", "reject_outliers",
           "outlier_flags"]


@dataclass
class TimingStats:
    """Per-call wall-clock statistics over the post-rejection samples."""
    times_us: List[float]
    n_outliers: int = 0

    @property
    def median(self) -> float:
        return statistics.median(self.times_us) if self.times_us else 0.0

    @property
    def mean(self) -> float:
        return statistics.fmean(self.times_us) if self.times_us else 0.0

    @property
    def best(self) -> float:
        return min(self.times_us) if self.times_us else 0.0

    @property
    def std(self) -> float:
        return statistics.pstdev(self.times_us) \
            if len(self.times_us) > 1 else 0.0

    def to_metrics(self) -> dict:
        """The flat metric dict every result row carries.  ``times_us``
        (the kept samples) rides along so the obs regression gate can use
        the cell's own measured spread instead of a percent threshold."""
        return {"us_median": self.median, "us_mean": self.mean,
                "us_min": self.best, "us_std": self.std,
                "n_trials": len(self.times_us),
                "n_outliers": self.n_outliers,
                "times_us": [round(t, 3) for t in self.times_us]}


def time_callable(fn: Callable[[], Any], *, warmup: int = 1,
                  repeats: int = 5, outlier_iqr: float = 3.0) -> TimingStats:
    """Wall-time ``fn`` (which must return a jax value to block on).
    ``warmup=0`` is honored: first-call compile cost lands in the timings
    (where the IQR rejection flags it as an outlier rather than letting it
    silently poison the median)."""
    tracer = get_tracer()
    traced = tracer.enabled
    warm_marks = []
    for _ in range(max(warmup, 0)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        if traced:
            warm_marks.append((t0, time.perf_counter()))
    times = []
    marks = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        t1 = time.perf_counter()
        times.append((t1 - t0) * 1e6)
        if traced:
            marks.append((t0, t1))
    flags = outlier_flags(times, outlier_iqr)
    kept = [t for t, cut in zip(times, flags) if not cut]
    if traced:
        for i, (w0, w1) in enumerate(warm_marks):
            tracer.record("warmup", w0, w1, trial=i, phase="warmup")
        for i, ((t0, t1), cut) in enumerate(zip(marks, flags)):
            tracer.record("timed", t0, t1, trial=i, phase="timed",
                          outlier=bool(cut))
    return TimingStats(times_us=kept, n_outliers=len(times) - len(kept))


def outlier_flags(times: List[float], k: float) -> List[bool]:
    """Per-sample rejection flags (True = slow outlier) under the one-sided
    median + k*IQR rule; the all-flagged case degrades to keeping all."""
    if len(times) < 4 or k <= 0:
        return [False] * len(times)
    s = sorted(times)
    q1 = s[len(s) // 4]
    q3 = s[(3 * len(s)) // 4]
    cut = statistics.median(s) + k * max(q3 - q1, 1e-9)
    flags = [t > cut for t in times]
    if all(flags):
        return [False] * len(times)
    return flags


def reject_outliers(times: List[float], k: float) -> List[float]:
    """Drop samples above median + k*IQR (one-sided: slow outliers only —
    preemptions / GC pauses inflate, nothing deflates, a timing)."""
    return [t for t, cut in zip(times, outlier_flags(times, k)) if not cut]
