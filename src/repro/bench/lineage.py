"""Lineage validation: catalog expectations vs published numbers.

The paper's core move (§6) is an *expectation model* — for any chip pair,
``T_speedup = min(FLOP ratio, BW ratio)`` — validated against measurements
across K80→A100.  This module closes the same loop for the catalog's
Hopper extension: it computes the expected speedups from ``core.hardware``
/ ``core.balance`` and compares them against a committed reference table of
published numbers (paper Table 1 derivations for the K80→A100 arc; the
Hopper microbenchmark papers, Luo et al. arXiv:2402.13499 / 2501.12084, for
A100→H100/H200), emitting one verdict row per pair:

  * ``within-band`` — catalog expectation within the pair's relative band,
  * ``over``        — catalog predicts *more* speedup than published,
  * ``under``       — catalog predicts *less*.

``over``/``under`` mean the catalog and the published record have drifted
apart (a mistyped chip row, or a reference number that needs re-sourcing) —
CI fails on either.  The reference table lives at
``experiments/baselines/LINEAGE_hopper.json``; the verdicts are rendered by
``experiments/make_report.py --lineage`` and gated by
``python -m repro.bench.cli lineage``.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, IO, List, Optional, Union

from ..core import hardware
from ..core.balance import expect_speedup

__all__ = ["LineagePair", "LineageVerdict", "load_reference",
           "validate", "lineage_chain", "to_doc", "default_reference_path",
           "REFERENCE_KIND", "REFERENCE_SCHEMA", "DOC_KIND"]

REFERENCE_KIND = "lineage-reference"
REFERENCE_SCHEMA = 1
DOC_KIND = "lineage-validation"


@dataclass(frozen=True)
class LineagePair:
    """One published chip-pair speedup the catalog must reproduce."""
    old: str
    new: str
    published: float             # published/derived speedup for the pair
    band: float                  # relative tolerance (0.15 = +-15%)
    precision: str = "f32"
    source: str = ""             # citation for ``published``
    note: str = ""


@dataclass(frozen=True)
class LineageVerdict:
    """A validated pair: catalog expectation vs the published number."""
    old: str
    new: str
    precision: str
    expected: float              # catalog min(FLOP ratio, BW ratio)
    flop_ratio: float
    bw_ratio: float
    binds: str                   # which ratio limits: "flops"|"bandwidth"
    published: float
    band: float
    rel_dev: float               # expected/published - 1
    verdict: str                 # "within-band" | "over" | "under"
    source: str = ""
    note: str = ""

    @property
    def ok(self) -> bool:
        return self.verdict == "within-band"


def default_reference_path() -> str:
    """The committed reference table, resolved relative to this checkout."""
    return os.path.normpath(os.path.join(
        os.path.dirname(__file__), "..", "..", "..",
        "experiments", "baselines", "LINEAGE_hopper.json"))


def load_reference(path_or_file: Union[str, IO]) -> List[LineagePair]:
    """Parse a lineage-reference JSON; raises ``ValueError`` on a wrong
    ``kind``/``schema`` or an unknown chip name (typos must not pass as
    silently-empty validations)."""
    if hasattr(path_or_file, "read"):
        doc = json.load(path_or_file)
    else:
        with open(path_or_file) as f:
            doc = json.load(f)
    if doc.get("kind") != REFERENCE_KIND:
        raise ValueError(f"not a {REFERENCE_KIND} document: "
                         f"kind={doc.get('kind')!r}")
    if doc.get("schema") != REFERENCE_SCHEMA:
        raise ValueError(f"unsupported {REFERENCE_KIND} schema "
                         f"{doc.get('schema')!r} (want {REFERENCE_SCHEMA})")
    pairs = []
    for row in doc.get("pairs", []):
        pair = LineagePair(
            old=row["old"], new=row["new"],
            published=float(row["published"]), band=float(row["band"]),
            precision=row.get("precision", "f32"),
            source=row.get("source", ""), note=row.get("note", ""))
        for name in (pair.old, pair.new):
            if name not in hardware.CATALOG:
                raise ValueError(f"reference pair {pair.old}->{pair.new} "
                                 f"names unknown chip {name!r}")
        if pair.published <= 0 or pair.band < 0:
            raise ValueError(f"reference pair {pair.old}->{pair.new} has "
                             f"non-positive published/band")
        pairs.append(pair)
    if not pairs:
        raise ValueError("reference table has no pairs")
    return pairs


def _judge(pair: LineagePair) -> LineageVerdict:
    exp = expect_speedup(hardware.get_chip(pair.old),
                         hardware.get_chip(pair.new), pair.precision)
    rel = exp.expected / pair.published - 1.0
    if rel > pair.band:
        verdict = "over"
    elif rel < -pair.band:
        verdict = "under"
    else:
        verdict = "within-band"
    return LineageVerdict(
        old=pair.old, new=pair.new, precision=pair.precision,
        expected=exp.expected, flop_ratio=exp.flop_ratio,
        bw_ratio=exp.bw_ratio, binds=exp.binds,
        published=pair.published, band=pair.band, rel_dev=rel,
        verdict=verdict, source=pair.source, note=pair.note)


def validate(pairs: List[LineagePair]) -> List[LineageVerdict]:
    """Judge every reference pair against the live catalog."""
    return [_judge(p) for p in pairs]


def lineage_chain(names: Optional[List[str]] = None,
                  precision: str = "f32") -> List[LineageVerdict]:
    """Consecutive-pair expectations along a lineage arc (default: the
    datacenter K80→…→H100 arc) with no published number to judge against —
    the 'what does the catalog itself predict' rows of the report.  These
    carry verdict "expected" and published/band/rel_dev of 0."""
    arc = list(names or hardware.DATACENTER_LINEAGE)
    out = []
    for old, new in zip(arc, arc[1:]):
        exp = expect_speedup(hardware.get_chip(old),
                             hardware.get_chip(new), precision)
        out.append(LineageVerdict(
            old=old, new=new, precision=precision,
            expected=exp.expected, flop_ratio=exp.flop_ratio,
            bw_ratio=exp.bw_ratio, binds=exp.binds,
            published=0.0, band=0.0, rel_dev=0.0, verdict="expected"))
    return out


def to_doc(verdicts: List[LineageVerdict],
           chain: Optional[List[LineageVerdict]] = None,
           reference: str = "") -> Dict:
    """The machine-readable validation document (make_report renders it)."""
    counts = {"within-band": 0, "over": 0, "under": 0}
    for v in verdicts:
        counts[v.verdict] = counts.get(v.verdict, 0) + 1
    return {
        "kind": DOC_KIND,
        "schema": 1,
        "reference": reference,
        "counts": counts,
        "ok": counts.get("over", 0) == 0 and counts.get("under", 0) == 0,
        "rows": [asdict(v) for v in verdicts],
        "chain": [asdict(v) for v in (chain or [])],
    }
