"""Declarative benchmark scenarios: kernel x shape x dtype x strategy.

A ``Scenario`` names one concrete workload — a Pallas kernel at a shape and
dtype, optionally pinned to one async ``Strategy`` and extra config/workload
parameters — so every paper figure and every ad-hoc experiment is an entry
in one registry: enumerable (``scenarios()``), filterable (``--only``,
``--kernel``, ``--strategy``, ``--tag``) and individually runnable
(``repro.bench.runner`` / ``python -m repro.bench.cli run``).

Input construction and the analytic (flops, bytes, vmem) models are shared
with the autotuner via ``tuning.search_space.SPECS`` — a scenario and a
tuning task of the same cell can never disagree about the workload.  What
this module adds on top is the *call adapter* (workload parameters such as
``iters``/``penalty`` that the tuner holds fixed) and the correctness oracle
from ``kernels.ref``.

Registering a new workload::

    from repro.bench.scenario import Scenario, register

    register(Scenario(name="mine/stream_hot", kernel="stream",
                      shape=(1024, 256), workload={"iters": 64},
                      tags=("mine",)))

``strategy=None`` means "whatever the resolved default is" — the tuning
registry's winner when one exists, the seed constant otherwise — which is
exactly what a production call site would get.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..core.async_pipeline import Strategy
from ..kernels import ops, ref
from ..tuning.search_space import KERNELS, SPECS

__all__ = ["Scenario", "ServeScenario", "register", "get_scenario",
           "scenarios", "scenario_names", "call_kernel", "check_output",
           "CHECK_TOL", "KERNELS", "SERVE_KERNEL"]

#: pseudo-kernel name marking end-to-end serving scenarios — they run the
#: model serving loop (repro.bench.serving), not a Pallas kernel, so they
#: bypass SPECS/CALLERS/roofline projection entirely.
SERVE_KERNEL = "serve"


@dataclass(frozen=True)
class Scenario:
    """One runnable benchmark cell."""
    name: str                            # unique, hierarchical: "fig3/..."
    kernel: str                          # key into tuning SPECS / ops
    shape: Tuple[int, ...]               # the SPECS shape convention
    dtype: str = "float32"
    strategy: Optional[Strategy] = None  # None -> resolved default/tuned
    config: Dict[str, Any] = field(default_factory=dict)   # tile overrides
    workload: Dict[str, Any] = field(default_factory=dict) # iters/penalty/..
    tags: Tuple[str, ...] = ()
    smoke: bool = False                  # include in `sweep --smoke`
    section: str = ""                    # paper figure/table it feeds

    def __post_init__(self):
        if self.kernel not in SPECS:
            raise KeyError(f"unknown kernel {self.kernel!r}; "
                           f"known: {tuple(SPECS)}")
        object.__setattr__(self, "shape",
                           tuple(int(s) for s in self.shape))

    def make_args(self) -> Tuple:
        return SPECS[self.kernel].make_args(self.shape, self.dtype)

    @property
    def is_serving(self) -> bool:
        return self.kernel == SERVE_KERNEL

    def matches(self, *, only: Optional[str] = None,
                kernel: Optional[str] = None,
                strategy: Optional[Strategy] = None,
                tag: Optional[str] = None,
                smoke: Optional[bool] = None) -> bool:
        if only is not None and not any(
                tok and tok in self.name for tok in only.split(",")):
            return False
        if kernel is not None and kernel != self.kernel:
            return False
        if strategy is not None and self.strategy not in (None, strategy):
            return False
        if tag is not None and tag not in self.tags:
            return False
        if smoke is not None and self.smoke != smoke:
            return False
        return True


@dataclass(frozen=True)
class ServeScenario(Scenario):
    """An end-to-end serving workload: scheduler x arrival trace.

    ``workload`` carries the trace/scheduler parameters consumed by
    ``repro.bench.serving.run_serve_scenario``: scheduler ("continuous" |
    "cohort"), arrival ("uniform" | "poisson" | "bursty"), n_requests,
    batch, rate, burst, prompt_lens [lo, hi], max_new [lo, hi], seed,
    block_len, arch.  ``shape`` is (batch, n_requests) for display."""
    kernel: str = SERVE_KERNEL
    shape: Tuple[int, ...] = ()

    def __post_init__(self):
        # no SPECS entry: serving scenarios are not kernel cells
        if self.kernel != SERVE_KERNEL:
            raise ValueError(f"ServeScenario.kernel must be "
                             f"{SERVE_KERNEL!r}, got {self.kernel!r}")
        object.__setattr__(self, "shape",
                           tuple(int(s) for s in self.shape))

    def make_args(self):
        raise TypeError("serving scenarios have no kernel args; run them "
                        "via repro.bench.serving.run_serve_scenario")


# ---------------------------------------------------------------------------
# Call adapters + correctness oracles
# ---------------------------------------------------------------------------

#: kernel -> fn(args, config, workload, interpret) -> jax value.  The config
#: dict holds exactly the KERNEL_DEFAULTS keys; workload holds the
#: non-tunable problem parameters a figure sweeps (intensity, penalty, ...).
CALLERS: Dict[str, Callable[..., Any]] = {
    "stream": lambda a, cfg, w, i: ops.stream(
        a[0], iters=w.get("iters", 4), interpret=i, **cfg),
    "hotspot": lambda a, cfg, w, i: ops.hotspot(
        a[0], a[1], iters=w.get("iters", 1), grid=w.get("grid", 1),
        interpret=i, **cfg),
    "pathfinder": lambda a, cfg, w, i: ops.pathfinder(
        a[0], interpret=i, **cfg),
    "nw": lambda a, cfg, w, i: ops.nw(
        a[0], penalty=w.get("penalty", 10), interpret=i, **cfg),
    "lud": lambda a, cfg, w, i: ops.lud(a[0], interpret=i, **cfg),
    "matmul": lambda a, cfg, w, i: ops.matmul(a[0], a[1], interpret=i,
                                              **cfg),
    "flash_attention": lambda a, cfg, w, i: ops.flash_attention(
        a[0], a[1], a[2], causal=w.get("causal", True), interpret=i, **cfg),
}

#: kernel -> fn(args, workload) -> reference output (kernels.ref oracle).
ORACLES: Dict[str, Callable[..., Any]] = {
    "stream": lambda a, w: ref.stream_ref(a[0], iters=w.get("iters", 4)),
    "hotspot": lambda a, w: ref.hotspot_ref(a[0], a[1],
                                            iters=w.get("iters", 1)),
    "pathfinder": lambda a, w: ref.pathfinder_ref(a[0]),
    "nw": lambda a, w: ref.nw_ref(a[0], w.get("penalty", 10)),
    "lud": lambda a, w: ref.lud_ref(a[0]),
    "matmul": lambda a, w: ref.matmul_ref(a[0], a[1]),
    "flash_attention": lambda a, w: ref.attention_ref(
        a[0], a[1], a[2], causal=w.get("causal", True)),
}

#: max |kernel - oracle| each kernel is held to in interpret mode.
CHECK_TOL: Dict[str, float] = {
    "stream": 1e-5, "hotspot": 1e-2, "pathfinder": 0.5, "nw": 1e-3,
    "lud": 1e-2, "matmul": 1e-2, "flash_attention": 2e-2,
}


def call_kernel(sc: Scenario, args: Tuple, config: Dict[str, Any],
                interpret: bool = True):
    return CALLERS[sc.kernel](args, config, sc.workload, interpret)


def check_output(sc: Scenario, args: Tuple, out) -> float:
    """Max abs error of ``out`` against the pure-jnp oracle.  Pathfinder's
    kernel returns a (1, cols) row; compare the row itself."""
    want = ORACLES[sc.kernel](args, sc.workload)
    got = out
    if sc.kernel == "pathfinder":
        got = jnp.asarray(out)[0]
    return float(jnp.max(jnp.abs(jnp.asarray(got, jnp.float32)
                                 - jnp.asarray(want, jnp.float32))))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_SCENARIOS: Dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    """Add ``sc`` to the global registry; re-registering the same name with
    a different definition is an error (silent shadowing hides typos)."""
    existing = _SCENARIOS.get(sc.name)
    if existing is not None and existing != sc:
        raise ValueError(f"scenario {sc.name!r} already registered "
                         f"with a different definition")
    _SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; run "
                       f"`python -m repro.bench.cli list`") from None


def scenarios(*, only: Optional[str] = None, kernel: Optional[str] = None,
              strategy: Optional[Strategy] = None, tag: Optional[str] = None,
              smoke: Optional[bool] = None) -> List[Scenario]:
    return [s for _, s in sorted(_SCENARIOS.items())
            if s.matches(only=only, kernel=kernel, strategy=strategy,
                         tag=tag, smoke=smoke)]


def scenario_names(**filters) -> List[str]:
    return [s.name for s in scenarios(**filters)]


# ---------------------------------------------------------------------------
# Default scenario set
# ---------------------------------------------------------------------------

#: shapes small enough that interpret mode on a CPU stays in milliseconds;
#: chosen to match the shapes the paper-figure benchmarks always used.
_SMOKE_SHAPES: Dict[str, Tuple[int, ...]] = {
    "stream": (256, 256),
    "hotspot": (32, 126),
    "pathfinder": (33, 128),
    "nw": (32,),
    "lud": (64,),
    "matmul": (256, 256, 256),
    "flash_attention": (2, 256, 64),
}

_SMOKE_WORKLOADS: Dict[str, Dict[str, Any]] = {
    "stream": {"iters": 4},
    "hotspot": {"iters": 2},
}


def _register_defaults() -> None:
    # one fast cell per kernel — the CI trajectory sweep
    for kernel, shape in _SMOKE_SHAPES.items():
        register(Scenario(
            name=f"smoke/{kernel}", kernel=kernel, shape=shape,
            workload=dict(_SMOKE_WORKLOADS.get(kernel, {})),
            tags=("smoke",), smoke=True, section="smoke"))

    # paper Fig. 3: the async-copy microbenchmark, strategy x intensity
    for strategy in Strategy:
        for iters in (1, 32):
            register(Scenario(
                name=f"fig3/stream/{strategy.value}/iters={iters}",
                kernel="stream", shape=(256, 256), strategy=strategy,
                config={"tile_rows": 16, "n_tiles": 8},
                workload={"iters": iters},
                tags=("fig3", "paper"), section="fig3"))

    # regime map: per kernel a sync baseline plus the kernel's best async
    # strategy at each ring depth — `sweep` folds the measurements into
    # per-cell "async pays / async hurts" verdict rows (bench.regime)
    for kernel, shape in _SMOKE_SHAPES.items():
        workload = dict(_SMOKE_WORKLOADS.get(kernel, {}))
        register(Scenario(
            name=f"regime/{kernel}/sync", kernel=kernel, shape=shape,
            strategy=Strategy.SYNC, workload=dict(workload),
            tags=("regime",), section="regime"))
        strat = (Strategy.DROP_OFF if kernel == "pathfinder"
                 else Strategy.OVERLAP)
        for depth in (2, 3, 4):
            register(Scenario(
                name=f"regime/{kernel}/{strat.value}/d{depth}",
                kernel=kernel, shape=shape, strategy=strat,
                config={"depth": depth}, workload=dict(workload),
                tags=("regime",), section="regime"))
            # Hopper-style bulk copies: the regime reducer takes the min
            # across async strategies per depth, so TMA rows slot in as a
            # second async contender rather than a new verdict family
            register(Scenario(
                name=f"regime/{kernel}/{Strategy.TMA.value}/d{depth}",
                kernel=kernel, shape=shape, strategy=Strategy.TMA,
                config={"depth": depth}, workload=dict(workload),
                tags=("regime",), section="regime"))

    # paper Fig. 4: the four Rodinia kernels x every async strategy
    fig4 = {
        "hotspot": ((32, 126), {"iters": 2}),
        "pathfinder": ((33, 128), {}),
        "nw": ((32,), {}),
        "lud": ((64,), {}),
    }
    for kernel, (shape, workload) in fig4.items():
        for strategy in Strategy:
            register(Scenario(
                name=f"fig4/{kernel}/{strategy.value}", kernel=kernel,
                shape=shape, strategy=strategy, workload=dict(workload),
                tags=("fig4", "paper"), section="fig4"))

    # serving: continuous batching vs the static-cohort baseline under
    # three deterministic arrival traces.  uniform is the small CI-gated
    # cell; poisson is the acceptance workload (mixed lengths at batch 4,
    # where slot-level refill shows its tokens/s win); bursty stresses
    # admission + queueing.  Not smoke-tagged: the serving CI step runs
    # them explicitly so the kernel trajectory sweep stays fast.
    serve_traces = {
        "uniform": dict(n_requests=6, batch=2, rate=0.5,
                        prompt_lens=[5, 16], max_new=[4, 8]),
        "poisson": dict(n_requests=16, batch=4, rate=0.5,
                        prompt_lens=[5, 24], max_new=[8, 40]),
        "bursty": dict(n_requests=8, batch=2, rate=0.5, burst=4,
                       prompt_lens=[5, 16], max_new=[4, 12]),
    }
    for arrival, wl in serve_traces.items():
        for sched in ("continuous", "cohort"):
            register(ServeScenario(
                name=f"serve/{arrival}/{sched}",
                shape=(wl["batch"], wl["n_requests"]),
                workload={"scheduler": sched, "arrival": arrival,
                          "seed": 0, "block_len": 8,
                          "arch": "qwen2-1.5b", **wl},
                tags=("serve",), section="serve"))

    # shared-prefix family: one poisson trace whose prompts share a
    # 64-token prefix in groups of 4 (system-prompt workload), run three
    # ways — monolithic prefill (the PR 8 baseline), chunked prefill, and
    # chunked + copy-on-write prefix sharing.  ``check_outputs`` on the
    # shared cell replays it without sharing and fails the bench unless
    # greedy outputs are bit-identical; the headline acceptance number is
    # shared vs chunked tokens/s + TTFT p99 on this trace.  chunk 16 =
    # 2 blocks: match length is capped to chunk multiples, so a chunk
    # finer than the prefix lets nearly all of it be shared.
    prefix_wl = dict(n_requests=16, batch=4, rate=0.25, seed=0,
                     block_len=8, arch="qwen2-1.5b", arrival="poisson",
                     prompt_lens=[5, 24], max_new=[8, 24],
                     prefix_len=64, prefix_group=4)
    for variant, extra in (
            ("baseline", {}),
            ("chunked", {"chunk_tokens": 16}),
            ("shared", {"chunk_tokens": 16, "prefix_cache": True,
                        "check_outputs": True})):
        register(ServeScenario(
            name=f"serve/prefix/{variant}",
            shape=(prefix_wl["batch"], prefix_wl["n_requests"]),
            workload={"scheduler": "continuous", **prefix_wl, **extra},
            tags=("serve", "prefix"), section="serve"))


_register_defaults()
