"""Execute scenarios: resolve config, measure, check, project, record.

The runner is the only place where a scenario meets the clock.  For each
``Scenario`` it

  1. resolves the kernel config — tuning-registry winner for this
     (kernel, shape, dtype, chip, mode) cell if one exists, the seed
     default otherwise, then scenario-pinned strategy/overrides on top —
     and records *which* of those happened (``config_source``);
  2. verifies the kernel against its ``kernels.ref`` oracle (``max_err``
     goes into the metrics; a benchmark is worthless if it is wrong);
  3. times it with the canonical ``repro.bench.timing`` protocol; and
  4. emits a schema-v2 ``BenchResult`` with full provenance.

``sweep`` additionally performs the paper's generation study: every
scenario is projected through the analytic roofline model
(``tuning.search_space.predict_time``) onto every registered ``Chip``
model, so one sweep yields the measured-on-this-host rows *plus* the
cross-lineage expectation rows the paper's Fig. 2/§6 analysis needs.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import hardware
from ..obs.trace import get_tracer
from ..tuning.autotuner import _default_registry, decode_config
from ..tuning.registry import Registry
from ..tuning.search_space import SPECS, predict_time
from ..kernels import ops
from ..kernels.stream import stream_flops_bytes
from .regime import regime_rows
from .results import BenchReport, BenchResult, now_iso
from .scenario import (CHECK_TOL, Scenario, call_kernel, check_output,
                       scenarios)
from .timing import time_callable

log = logging.getLogger("repro.bench")

__all__ = ["RunOptions", "resolve_config", "run_scenario", "run_scenarios",
           "project_scenario", "sweep", "new_report"]


@dataclass
class RunOptions:
    """Measurement policy for a batch of scenario runs."""
    warmup: int = 1
    repeats: int = 5
    interpret: bool = True              # Pallas interpreter vs compiled
    check: bool = True                  # compare against the ref oracle
    use_tuned: bool = True              # consult the tuning registry
    chip: Optional[str] = None          # provenance chip (default: TARGET)
    registry: Optional[Registry] = None
    emit: Optional[Callable[[BenchResult], None]] = None  # streaming hook

    def resolved_chip(self) -> str:
        return self.chip or hardware.TARGET.name


def new_report() -> BenchReport:
    return BenchReport(jax_version=jax.__version__,
                       backend=jax.default_backend(),
                       created_at=now_iso())


def resolve_config(sc: Scenario, opts: RunOptions
                   ) -> Tuple[Dict[str, object], str, Optional[str]]:
    """(config, source, tuned_key) for this scenario on this chip/mode."""
    cfg = ops.default_config(sc.kernel)
    source, tuned_key = "default", None
    if opts.use_tuned:
        # the memoized process-wide registry: a sweep must not re-parse
        # tuning_registry.json once per scenario
        reg = opts.registry if opts.registry is not None \
            else _default_registry()
        rec = reg.get(sc.kernel, sc.shape, sc.dtype, opts.resolved_chip(),
                      opts.interpret)
        if rec is not None:
            cfg = decode_config(rec.best)
            source, tuned_key = "tuned", rec.key
    if sc.strategy is not None or sc.config:
        cfg = dict(cfg)
        if sc.strategy is not None:
            cfg["strategy"] = sc.strategy
        cfg.update(sc.config)
        source += "+scenario"
    return cfg, source, tuned_key


def _flops_bytes(sc: Scenario, cfg: Dict[str, object]) -> Tuple[float, float]:
    """Analytic work/traffic for the scenario's actual workload.  The tuner
    times at a fixed intensity; scenarios sweep it, so honor the scenario's
    ``iters`` where the kernel spec models a single iteration."""
    if sc.kernel == "stream":
        return stream_flops_bytes(sc.shape, sc.workload.get("iters", 4),
                                  jnp.dtype(sc.dtype).itemsize)
    flops, nbytes = SPECS[sc.kernel].flops_bytes(sc.shape, sc.dtype, cfg)
    if sc.kernel == "hotspot":          # spec models iters=1; scale both
        iters = sc.workload.get("iters", 1)
        flops, nbytes = flops * iters, nbytes * iters
    return flops, nbytes


def _strategy_name(cfg: Dict[str, object]) -> str:
    s = cfg.get("strategy")
    return getattr(s, "value", str(s))


def run_scenario(sc: Scenario, opts: Optional[RunOptions] = None, *,
                 resolved: Optional[Tuple] = None) -> BenchResult:
    """Measure one scenario on this host and return its result row.
    ``resolved`` short-circuits config resolution when the caller (sweep)
    already did it for this scenario."""
    opts = opts or RunOptions()
    if sc.is_serving:
        # end-to-end serving cell: no kernel config, oracle, or roofline
        from .serving import run_serve_scenario
        return run_serve_scenario(sc, opts)
    cfg, source, tuned_key = resolved or resolve_config(sc, opts)
    args = sc.make_args()
    fn = lambda: call_kernel(sc, args, cfg, opts.interpret)

    metrics: Dict[str, object] = {}
    # the scenario span carries the full config provenance, so a Perfetto
    # view of a sweep shows *what* ran in each box, not just how long
    with get_tracer().span(
            f"scenario:{sc.name}", kernel=sc.kernel,
            shape="x".join(map(str, sc.shape)), dtype=sc.dtype,
            strategy=_strategy_name(cfg), config_source=source,
            tuned_key=tuned_key, chip=opts.resolved_chip(),
            interpret=opts.interpret, repeats=opts.repeats) as span:
        warmup = opts.warmup
        if opts.check:
            # the oracle call compiles and runs the kernel, so it doubles
            # as one warmup iteration — interpret-mode calls cost seconds
            with get_tracer().span("oracle"):
                out = jax.block_until_ready(fn())
                err = check_output(sc, args, out)
            warmup = max(warmup - 1, 0)
            metrics["max_err"] = err
            metrics["check_ok"] = bool(err <= CHECK_TOL[sc.kernel])
            if not metrics["check_ok"]:
                log.warning("scenario %s: max_err %.3g exceeds tol %.3g",
                            sc.name, err, CHECK_TOL[sc.kernel])
        stats = time_callable(fn, warmup=warmup, repeats=opts.repeats)
        metrics.update(stats.to_metrics())
        if span is not None:
            span.attrs["us_median"] = stats.median

    flops, nbytes = _flops_bytes(sc, cfg)
    metrics["intensity"] = flops / nbytes if nbytes else 0.0
    metrics["predicted_us"] = predict_time(
        cfg["strategy"], flops, nbytes, depth=int(cfg.get("depth", 2)),
        n_tiles=SPECS[sc.kernel].n_tiles(sc.shape, cfg),
        wait_group=cfg.get("wait_group"),
        chip=hardware.get_chip(opts.resolved_chip())) * 1e6

    result = BenchResult(
        scenario=sc.name, kernel=sc.kernel, shape=list(sc.shape),
        dtype=sc.dtype, strategy=_strategy_name(cfg),
        chip=opts.resolved_chip(), metrics=metrics,
        config={k: getattr(v, "value", v) for k, v in cfg.items()},
        config_source=source, tuned_key=tuned_key,
        trace_id=span.span_id if span is not None else None,
        kind="measured", section=sc.section, interpret=opts.interpret,
        backend=jax.default_backend(), jax_version=jax.__version__,
        created_at=now_iso())
    if opts.emit:
        opts.emit(result)
    return result


def project_scenario(sc: Scenario, chip_name: str,
                     opts: Optional[RunOptions] = None, *,
                     resolved: Optional[Tuple] = None) -> BenchResult:
    """Roofline-model expectation row for ``sc`` on ``chip_name`` — the
    paper's cross-generation methodology where the hardware itself is not
    attached to this host."""
    if sc.is_serving:
        raise ValueError(f"serving scenario {sc.name!r} has no roofline "
                         "projection")
    opts = opts or RunOptions()
    cfg, source, tuned_key = resolved or resolve_config(sc, opts)
    chip = hardware.get_chip(chip_name)
    flops, nbytes = _flops_bytes(sc, cfg)
    t_c = flops / (chip.tflops_f32 * 1e12)
    t_m = nbytes / (chip.mem_bw_gbs * 1e9)
    t = predict_time(cfg["strategy"], flops, nbytes,
                     depth=int(cfg.get("depth", 2)),
                     n_tiles=SPECS[sc.kernel].n_tiles(sc.shape, cfg),
                     wait_group=cfg.get("wait_group"), chip=chip)
    metrics = {"predicted_us": t * 1e6, "t_compute_us": t_c * 1e6,
               "t_memory_us": t_m * 1e6,
               "intensity": flops / nbytes if nbytes else 0.0,
               "bound": "compute" if t_c > t_m else "memory"}
    result = BenchResult(
        scenario=sc.name, kernel=sc.kernel, shape=list(sc.shape),
        dtype=sc.dtype, strategy=_strategy_name(cfg), chip=chip_name,
        metrics=metrics,
        config={k: getattr(v, "value", v) for k, v in cfg.items()},
        config_source=source, tuned_key=tuned_key, kind="model",
        section=sc.section or "lineage", interpret=opts.interpret,
        backend="", jax_version=jax.__version__, created_at=now_iso())
    if opts.emit:
        opts.emit(result)
    return result


def run_scenarios(scs: Sequence[Scenario],
                  opts: Optional[RunOptions] = None) -> BenchReport:
    """Measure a batch of scenarios into one report."""
    opts = opts or RunOptions()
    report = new_report()
    for sc in scs:
        report.add(run_scenario(sc, opts))
    return report


def sweep(scs: Optional[Sequence[Scenario]] = None,
          chips: Optional[Sequence[str]] = None,
          opts: Optional[RunOptions] = None) -> BenchReport:
    """The generation sweep: measure every scenario on this host, then
    project each one across the chip lineage (default: every registered
    ``Chip`` model, GPUs and TPUs alike)."""
    opts = opts or RunOptions()
    if scs is None:
        scs = scenarios(smoke=True)
    if chips is None:
        chips = list(hardware.CATALOG)
    for name in chips:
        hardware.get_chip(name)         # fail fast on a typo'd chip
    report = new_report()
    with get_tracer().span("sweep", n_scenarios=len(scs),
                           n_chips=len(chips)):
        for sc in scs:
            if sc.is_serving:
                # serving cells have no roofline model to project
                report.add(run_scenario(sc, opts))
                continue
            resolved = resolve_config(sc, opts)     # once per scenario
            report.add(run_scenario(sc, opts, resolved=resolved))
            for chip_name in chips:
                report.add(project_scenario(sc, chip_name, opts,
                                            resolved=resolved))
    # fold any regime/* depth-sweep measurements into per-cell
    # "async pays / async hurts" verdict rows (kind="regime")
    for row in regime_rows(report.results):
        report.add(row)
        if opts.emit:
            opts.emit(row)
    return report
