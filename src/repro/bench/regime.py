"""Fold depth-sweep measurements into the paper's "async pays / async
hurts" regime map.

The ``regime/*`` scenario family measures, per kernel x shape x dtype
cell, a SYNC baseline plus the kernel's best async strategy at each ring
depth.  This module reduces those measured rows into one verdict row per
cell:

  verdict            "pays" | "neutral" | "hurts"  (±PAYS_MARGIN vs sync)
  break_even_depth   smallest ring depth that beats (or ties) the sync
                     baseline, or None if no depth ever does
  best_depth         the depth with the lowest measured median
  speedup            sync_us / best_us

A verdict row is a normal schema-v2 ``BenchResult`` with ``kind="regime"``
so it travels in the same BENCH_*.json artifact as the measurements it
summarizes, and ``experiments/make_report.py`` can render the map without
re-deriving it.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from .results import BenchResult, now_iso

__all__ = ["PAYS_MARGIN", "regime_rows"]

#: relative margin vs the sync baseline inside which a cell is "neutral" —
#: interpreter/CPU timing jitter makes a tighter call meaningless.
PAYS_MARGIN = 0.05


def _cell_key(r: BenchResult) -> Tuple[str, Tuple[int, ...], str]:
    return (r.kernel, tuple(r.shape), r.dtype)


def regime_rows(rows: Iterable[BenchResult]) -> List[BenchResult]:
    """Reduce measured ``section == "regime"`` rows to one verdict row per
    (kernel, shape, dtype) cell.  Cells missing their sync baseline or any
    async measurement are skipped (a partial sweep yields a partial map,
    never a fabricated verdict)."""
    cells: Dict[Tuple[str, Tuple[int, ...], str], List[BenchResult]] = {}
    for r in rows:
        if r.section == "regime" and r.kind == "measured":
            cells.setdefault(_cell_key(r), []).append(r)

    out: List[BenchResult] = []
    for (kernel, shape, dtype), grp in sorted(cells.items()):
        baseline = next((r for r in grp if r.strategy == "sync"), None)
        if baseline is None:
            continue
        base_us = baseline.metrics.get("us_median")
        if not base_us:
            continue

        # best async median per ring depth (min across strategies if a
        # future sweep measures several per depth)
        us_by_depth: Dict[int, float] = {}
        strat_by_depth: Dict[int, str] = {}
        for r in grp:
            if r.strategy == "sync":
                continue
            us = r.metrics.get("us_median")
            if us is None:
                continue
            depth = int(r.config.get("depth", 2))
            if depth not in us_by_depth or us < us_by_depth[depth]:
                us_by_depth[depth] = float(us)
                strat_by_depth[depth] = r.strategy
        if not us_by_depth:
            continue

        depths = sorted(us_by_depth)
        best_depth = min(depths, key=lambda d: (us_by_depth[d], d))
        best_us = us_by_depth[best_depth]
        break_even: Optional[int] = next(
            (d for d in depths if us_by_depth[d] <= base_us), None)
        if best_us < base_us * (1.0 - PAYS_MARGIN):
            verdict = "pays"
        elif best_us > base_us * (1.0 + PAYS_MARGIN):
            verdict = "hurts"
        else:
            verdict = "neutral"

        metrics: Dict[str, object] = {
            "baseline_us": float(base_us),
            "best_depth": best_depth,
            "best_us": best_us,
            "break_even_depth": break_even,
            "speedup": float(base_us) / best_us if best_us else 0.0,
            "verdict": verdict,
        }
        for d in depths:
            metrics[f"us_d{d}"] = us_by_depth[d]

        out.append(BenchResult(
            scenario=f"regime/{kernel}/map", kernel=kernel,
            shape=list(shape), dtype=dtype,
            strategy=strat_by_depth[best_depth], chip=baseline.chip,
            metrics=metrics, config={}, config_source="derived",
            kind="regime", section="regime",
            interpret=baseline.interpret, backend=baseline.backend,
            jax_version=baseline.jax_version, created_at=now_iso()))
    return out
