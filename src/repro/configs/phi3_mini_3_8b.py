"""phi3-mini-3.8b [dense]: 32L d=3072 32H (kv=32, i.e. MHA) ff=8192
vocab=32064.  RoPE + SwiGLU.  [arXiv:2404.14219]

Full attention only => long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", rope_theta=10000.0, chunk=1024),
)

SMOKE = ArchConfig(
    name="phi3-mini-3.8b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", chunk=16),
)
