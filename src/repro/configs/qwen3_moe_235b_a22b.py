"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4, head_dim=128)
expert ff=1536 vocab=151936, 128 experts top-8 (no shared).
[hf:Qwen/Qwen3-30B-A3B scaled family]

Expert weights are EP-sharded over "model" (8 experts/chip on TP=16) and
FSDP-sharded over the data axes (DESIGN.md SS5).  Full attention =>
long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", rope_theta=1000000.0, chunk=1024),
    moe=MoEConfig(n_experts=128, top_k=8, n_shared=0, d_ff_expert=1536,
                  capacity_factor=1.25),
)

SMOKE = ArchConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=0, vocab=512,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", chunk=16),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff_expert=32),
)
