"""xlstm-1.3b [ssm]: 48L d=2048 4H vocab=50304, d_ff=0 (blocks carry their
own projections).  mLSTM blocks with an sLSTM block every 8th layer (the
paper's xLSTM[7:1] ratio).  [arXiv:2405.04517]

Sub-quadratic recurrence => runs long_500k.
"""
from ..core.config import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="none"),
    ssm=SSMConfig(kind="xlstm", slstm_every=8, expand=2, chunk=64),
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=512,
    attn=AttnConfig(kind="none"),
    ssm=SSMConfig(kind="xlstm", slstm_every=2, expand=2, chunk=8),
)
