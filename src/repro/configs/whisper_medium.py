"""whisper-medium [audio]: enc-dec, 24+24L d=1024 16H (kv=16) ff=4096
vocab=51865 (padded to a TP multiple).  LayerNorm + GELU + sinusoidal
positions; the conv audio frontend is a STUB: input_specs() supplies
precomputed frame embeddings.  [arXiv:2212.04356]

Shapes: seq_len splits as frames = seq//2 encoder, tokens = seq//2 decoder
(train/prefill); decode uses a 1500-frame encoder memory (whisper's fixed
30 s window) + a seq_len self-attention cache.  Full attention =>
long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig

ENC_FRAMES_DECODE = 1500

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    act="gelu", norm="layernorm",
    attn=AttnConfig(kind="full", rope_theta=0.0, chunk=1024),
)

SMOKE = ArchConfig(
    name="whisper-medium-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    act="gelu", norm="layernorm",
    attn=AttnConfig(kind="full", rope_theta=0.0, chunk=16),
)
