"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) ff=22016 vocab=102400.
Llama architecture (RMSNorm, SwiGLU, RoPE, untied).  [arXiv:2401.02954]

Full attention only => long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", rope_theta=10000.0, chunk=1024),
)

SMOKE = ArchConfig(
    name="deepseek-67b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=172, vocab=512,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", chunk=16),
)
