"""phi-3-vision-4.2b [vlm]: phi3-mini backbone (32L d=3072 32H kv=32 ff=8192
vocab=32064) + CLIP frontend.  The vision tower is a STUB per the assignment:
input_specs() supplies precomputed patch embeddings (B, 256, d_model), which
a learned projection maps into the token stream.
[hf:microsoft/Phi-3-vision-128k-instruct]

Full attention => long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, n_patches=256,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", rope_theta=10000.0, chunk=1024),
)

SMOKE = ArchConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, n_patches=8,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="full", chunk=16),
)
