"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) ff=5504 vocab=32001,
ssm_state=16.  Parallel attention + Mamba heads fused per layer; sliding-
window attention (1024) on all but 3 global layers (first/middle/last).
[arXiv:2411.13676]

25 heads pad to 32 for TP=16 (exact; zero out-proj rows).  SWA + SSM =>
sub-quadratic => runs long_500k (global layers attend the full half-meg
context through the seq-sharded cache).
"""
from ..core.config import ArchConfig, AttnConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="sliding", window=1024, rope_theta=10000.0,
                    chunk=1024),
    ssm=SSMConfig(kind="mamba", d_state=16, expand=2, chunk=64),
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=3, d_model=40, n_heads=5, n_kv_heads=5,
    d_ff=96, vocab=512,
    act="swiglu", norm="rmsnorm",
    attn=AttnConfig(kind="sliding", window=8, chunk=16),
    ssm=SSMConfig(kind="mamba", d_state=4, expand=2, chunk=8),
)
