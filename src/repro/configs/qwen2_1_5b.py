"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) ff=8960 vocab=151936.
QKV bias, tied embeddings.  [arXiv:2407.10671]

12 heads do not divide TP=16: heads are padded to 16 (zero out-projection
rows keep it exact; see DESIGN.md).  Full attention => long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    act="swiglu", norm="rmsnorm", tie_embeddings=True,
    attn=AttnConfig(kind="full", rope_theta=1000000.0, qkv_bias=True,
                    chunk=1024),
)

SMOKE = ArchConfig(
    name="qwen2-1.5b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    d_ff=140, vocab=512,
    act="swiglu", norm="rmsnorm", tie_embeddings=True,
    attn=AttnConfig(kind="full", qkv_bias=True, chunk=16),
)
