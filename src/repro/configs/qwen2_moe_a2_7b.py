"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (kv=16) expert ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts, QKV bias.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

60 experts pad to 64 for EP divisibility on TP=16 (router masks the pads).
Full attention => long_500k skipped.
"""
from ..core.config import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=151936,
    act="swiglu", norm="rmsnorm", tie_embeddings=True,
    attn=AttnConfig(kind="full", rope_theta=1000000.0, qkv_bias=True,
                    chunk=1024),
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_ff_expert=1408,
                  capacity_factor=1.25),
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512,
    act="swiglu", norm="rmsnorm", tie_embeddings=True,
    attn=AttnConfig(kind="full", qkv_bias=True, chunk=16),
    moe=MoEConfig(n_experts=6, top_k=2, n_shared=2, d_ff_expert=32),
)
