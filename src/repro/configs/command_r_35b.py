"""command-r-35b [dense]: 40L d=8192 64H (GQA kv=8) ff=22528 vocab=256000.
Cohere arch: parallel attention+MLP residual, layernorm, no biases, tied
embeddings, RoPE.  [hf:CohereForAI/c4ai-command-r-v01]

Full attention only => long_500k is skipped (DESIGN.md SS-Arch-applicability).
"""
from ..core.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000,
    act="swiglu", norm="layernorm", parallel_residual=True,
    tie_embeddings=True,
    attn=AttnConfig(kind="full", rope_theta=10000.0, qkv_bias=False,
                    chunk=1024),
)

SMOKE = ArchConfig(
    name="command-r-35b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=176, vocab=512,
    act="swiglu", norm="layernorm", parallel_residual=True,
    tie_embeddings=True,
    attn=AttnConfig(kind="full", rope_theta=10000.0, chunk=16),
)
