"""Architecture config registry: ``get_config("<arch-id>")`` and
``get_smoke_config("<arch-id>")`` for every assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..core.config import ArchConfig

_MODULES = {
    "command-r-35b": "command_r_35b",
    "deepseek-67b": "deepseek_67b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen2-1.5b": "qwen2_1_5b",
    "xlstm-1.3b": "xlstm_1_3b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-medium": "whisper_medium",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get_config(name: str) -> ArchConfig:
    return _mod(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _mod(name).SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
