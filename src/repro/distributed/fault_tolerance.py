"""Fault tolerance for long multi-pod runs.

Three mechanisms, all exercised by tests:

1. **Preemption-safe training** — SIGTERM/SIGINT installs a "save at next
   step boundary" flag; the runner checkpoints and exits with a restartable
   code instead of dying mid-step.
2. **Step retry with backoff** — transient device/IO errors re-run the step
   from the last good on-device state (synchronous SPMD means a failed step
   has no partial effects once inputs are re-fed deterministically).
3. **Elastic restart** — restore onto a *different* mesh (scale up/down or
   drop a failed pod): checkpoints store full logical arrays, the restore
   path re-shards onto the target topology, and the data pipeline replays
   from (seed, step), so the trajectory is preserved.

Straggler mitigation at SPMD scale is topology-level: the runner tracks a
rolling step-time watermark; when a step exceeds ``straggler_factor`` x the
median it records the event and (in a real deployment) triggers the elastic
path minus the slow pod.  On this single-host container the detection logic
is what tests cover.
"""
from __future__ import annotations

import logging
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

log = logging.getLogger("repro.ft")

EXIT_PREEMPTED = 143


class PreemptionGuard:
    """Install signal handlers that request a graceful stop."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:          # non-main thread (tests)
                pass
        return self

    def _handler(self, signum, frame):
        log.warning("preemption signal %s received; will save and exit "
                    "at the next step boundary", signum)
        self.requested = True

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


@dataclass
class StepStats:
    times: List[float] = field(default_factory=list)
    straggler_events: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float, factor: float = 3.0) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.times.append(dt)
        window = self.times[-50:]
        med = sorted(window)[len(window) // 2]
        is_straggler = len(window) >= 5 and dt > factor * med
        if is_straggler:
            self.straggler_events.append(step)
            log.warning("straggler step %d: %.3fs vs median %.3fs",
                        step, dt, med)
        return is_straggler


def run_with_retries(step_fn: Callable, *, max_retries: int = 3,
                     backoff: float = 0.1,
                     retryable=(RuntimeError, OSError)):
    """Run one training step with transient-failure retries."""
    for attempt in range(max_retries + 1):
        try:
            return step_fn()
        except retryable as e:                     # pragma: no cover - timing
            if attempt == max_retries:
                raise
            log.warning("step failed (%s); retry %d/%d", e, attempt + 1,
                        max_retries)
            time.sleep(backoff * (2 ** attempt))
