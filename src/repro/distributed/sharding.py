"""Logical-axis sharding rules.

Model code annotates arrays with *logical* axis names ("batch", "heads",
"mlp", "vocab", "experts", ...).  A ShardingRules context maps those names to
physical mesh axes; outside any context (single-device tests) annotations are
no-ops.  This is the MaxText-style indirection that lets one model definition
run on any mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


class ShardingRules(NamedTuple):
    mesh: Mesh
    rules: dict          # logical name -> physical mesh axis (or tuple / None)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        phys = []
        for a in axes:
            if a is None:
                phys.append(None)
            else:
                phys.append(self.rules.get(a))
        return P(*phys)

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))


_ctx = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def _axis_prod(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, str):
        phys = (phys,)
    return int(np.prod([mesh.shape[p] for p in phys]))


def safe_spec(rules: "ShardingRules", axes: Sequence[Optional[str]],
              shape: Sequence[int]) -> P:
    """Like rules.spec but drops mappings that do not divide the dim size
    (zero-size state fields, odd head counts on tiny smoke configs), and
    truncates/pads the axes to the value's rank (placeholder state fields
    may have fewer dims than the full-rank annotation)."""
    shape = tuple(shape)
    axes = tuple(axes)[:len(shape)]
    axes = axes + (None,) * (len(shape) - len(axes))
    out = []
    used = set()
    for a, dim in zip(axes, shape):
        phys = rules.rules.get(a) if a is not None else None
        if phys is not None:
            n = _axis_prod(rules.mesh, phys)
            if dim == 0 or n == 0 or dim % n != 0:
                phys = None
        if phys is not None:
            names = phys if isinstance(phys, tuple) else (phys,)
            if any(p in used for p in names):
                phys = None          # a mesh axis may appear only once
            else:
                used.update(names)
        out.append(phys)
    return P(*out)


def logical(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o context).
    Mappings that do not divide the dimension are dropped."""
    r = current_rules()
    if r is None:
        return x
    spec = safe_spec(r, axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def axis_size(logical_name: str) -> int:
    """Product of mesh-axis sizes a logical name maps to (1 w/o context)."""
    r = current_rules()
    if r is None:
        return 1
    phys = r.rules.get(logical_name)
    if phys is None:
        return 1
    if isinstance(phys, str):
        phys = (phys,)
    return int(np.prod([r.mesh.shape[p] for p in phys]))


def mesh_or_none() -> Optional[Mesh]:
    r = current_rules()
    return r.mesh if r is not None else None


def default_rules(mesh: Mesh, *, shard_kv: bool = True,
                  fsdp: bool = False, seq_shard: bool = False) -> ShardingRules:
    """Physical mapping for the production meshes.

    batch   -> all data-like axes ("pod" included when present)
    heads / mlp / vocab / experts -> "model" (tensor/expert parallelism)
    kv      -> "model" when the arch's kv-head count divides the TP degree
    embed   -> data axes when fsdp=True (ZeRO-3-style param sharding)
    seq     -> data axes when seq_shard=True (sequence parallelism)
    """
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names) or None
    model = "model" if "model" in names else None
    rules = {
        "batch": data_axes,
        "heads": model,
        "kv": model if shard_kv else None,
        "mlp": model,
        "vocab": model,
        "experts": model,
        "embed": data_axes if fsdp else None,
        "seq": data_axes if seq_shard else None,
        "kvlen": None,
        "residual": None,      # activation residual-stream dim (SP target)
        "state": None,
        # expert-weight ff dim: FSDP-sharded over the data axes always (the
        # qwen3-moe expert stack is 908 GB fp32 — TP alone cannot hold it)
        "expert_shard": data_axes,
    }
    return ShardingRules(mesh, rules)


# ---------------------------------------------------------------------------
# Param annotation: initializers return Param(value, logical_axes); these
# helpers split the tree into (values, specs/shardings).
# ---------------------------------------------------------------------------

class Param(NamedTuple):
    value: jax.Array
    axes: Tuple[Optional[str], ...]


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_tree(tree):
    """-> (value_tree, axes_tree)."""
    vals = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return vals, axes


def tree_shardings(axes_tree, rules: ShardingRules):
    return jax.tree.map(
        lambda axes: rules.sharding(axes), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def tree_shardings_safe(axes_tree, shapes_tree, rules: ShardingRules):
    """NamedShardings with non-divisible mappings dropped per-leaf."""
    def leaf(axes, shp):
        return NamedSharding(rules.mesh, safe_spec(rules, axes, shp.shape))
    return jax.tree.map(
        leaf, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def tree_specs(axes_tree, rules: ShardingRules):
    return jax.tree.map(
        lambda axes: rules.spec(axes), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
