"""Distribution substrate: logical sharding rules + fault tolerance."""
from . import sharding
from .sharding import (Param, ShardingRules, default_rules, logical,
                       split_tree, use_rules)

__all__ = ["sharding", "Param", "ShardingRules", "default_rules", "logical",
           "split_tree", "use_rules"]
