"""Encoder-decoder (whisper-medium backbone).

The audio conv frontend is a stub per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, T_enc, d).  Encoder layers are
bidirectional self-attention + GELU MLP with layernorm; decoder layers add
causal self-attention (KV-cached at decode) and cross-attention to the
encoder output (cross K/V computed once at prefill and carried in the
state).  Positions are sinusoidal (DESIGN.md §6 notes the learned-positions
simplification).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.config import ArchConfig
from ..distributed.sharding import Param, logical, split_tree
from . import attention as attn
from .layers import (embed, embed_init, linear, linear_init, mlp, mlp_init,
                     norm, norm_init, padded_heads, padded_vocab)
from .transformer import sinusoid, unembed as _unembed_with  # reuse vocab mask


class EncDecState(NamedTuple):
    k: jax.Array          # (Ld, B, W, KV, hd) decoder self-attn cache
    v: jax.Array
    kpos: jax.Array
    xk: jax.Array         # (Ld, B, T_enc, KV, hd) cross-attn keys
    xv: jax.Array
    pos: jax.Array        # (B,)


def _enc_layer_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": norm_init(cfg.d_model, cfg.norm),
        "attn": attn.attn_init(ks[0], cfg),
        "ln_mlp": norm_init(cfg.d_model, cfg.norm),
        "mlp": mlp_init(ks[1], cfg),
    }


def _dec_layer_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln_self": norm_init(cfg.d_model, cfg.norm),
        "self": attn.attn_init(ks[0], cfg),
        "ln_cross": norm_init(cfg.d_model, cfg.norm),
        "cross": attn.attn_init(ks[1], cfg),
        "ln_mlp": norm_init(cfg.d_model, cfg.norm),
        "mlp": mlp_init(ks[2], cfg),
    }


def _stack_init(fn, key, n, cfg):
    axes_box = {}

    def stripped(k):
        vals, axes = split_tree(fn(k, cfg))
        axes_box["axes"] = axes
        return vals

    vals = jax.vmap(stripped)(jax.random.split(key, n))
    return jax.tree.map(lambda arr, ax: Param(arr, (None,) + ax),
                        vals, axes_box["axes"])


def encdec_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 5)
    return {
        "embed": embed_init(ks[0], padded_vocab(cfg), cfg.d_model),
        "enc_layers": _stack_init(_enc_layer_init, ks[1], cfg.n_enc_layers, cfg),
        "dec_layers": _stack_init(_dec_layer_init, ks[2], cfg.n_layers, cfg),
        "ln_enc": norm_init(cfg.d_model, cfg.norm),
        "ln_f": norm_init(cfg.d_model, cfg.norm),
        "unembed": linear_init(ks[3], cfg.d_model, padded_vocab(cfg),
                               ("embed", "vocab")),
    }


def _mk_idx(cfg):
    hp = padded_heads(cfg)
    return attn.kv_index_map(cfg.n_heads, cfg.n_kv_heads, hp)


def encode(params, cfg: ArchConfig, frames, *, remat: bool = True):
    """frames: (B, T, d) stub embeddings -> (B, T, d)."""
    cdt = jnp.dtype(cfg.dtype)
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = frames.astype(cdt) + sinusoid(positions, cfg.d_model).astype(cdt)
    x = logical(x, "batch", "seq", "residual")
    idx = _mk_idx(cfg)

    def layer(x, p):
        h = norm(p["ln_attn"], x)
        q, k, v = attn.qkv_project(p["attn"], h, cfg, positions, cdt)
        o = attn.attend_chunked(q, k, v, idx, causal=False, window=0,
                                chunk=cfg.attn.chunk)
        x = x + attn.attn_out(p["attn"], o, cfg, cdt)
        x = x + mlp(p["mlp"], norm(p["ln_mlp"], x), cfg.act, cdt)
        return x, None

    f = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else layer
    x, _ = jax.lax.scan(f, x, params["enc_layers"])
    return norm(params["ln_enc"], x)


def _decoder(params, cfg: ArchConfig, tokens, enc_out, *, mode: str,
             state: Optional[EncDecState], remat: bool,
             budget=None):
    cdt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    idx = _mk_idx(cfg)
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_

    if mode == "decode":
        positions = state.pos[:, None]
    else:
        s = tokens.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed(params["embed"], tokens, cdt)
    x = x + sinusoid(positions, cfg.d_model).astype(cdt)

    if state is None:
        w = 0 if mode == "train" else max(budget or 0, tokens.shape[1])
        L = cfg.n_layers
        t_enc = enc_out.shape[1] if enc_out is not None else 0
        state = EncDecState(
            k=jnp.zeros((L, b, w, nkv, hd), cdt),
            v=jnp.zeros((L, b, w, nkv, hd), cdt),
            kpos=jnp.full((L, b, w), -1, jnp.int32),
            xk=jnp.zeros((L, b, t_enc, nkv, hd), cdt),
            xv=jnp.zeros((L, b, t_enc, nkv, hd), cdt),
            pos=jnp.zeros((b,), jnp.int32),
        )

    def layer(x, per):
        p, cache = per
        # --- causal self-attention (+ cache)
        h = norm(p["ln_self"], x)
        q, k, v = attn.qkv_project(p["self"], h, cfg, positions, cdt)
        if mode == "decode":
            ck, cv, cp = attn.update_cache_layer(
                cache["k"], cache["v"], cache["kp"], k, v, positions)
            o = attn.attend_decode(q, ck, cv, cp, idx,
                                   q_position=positions[:, 0])
            new_cache = dict(cache, k=ck, v=cv, kp=cp)
        else:
            o = attn.attend_chunked(q, k, v, idx, causal=True, window=0,
                                    chunk=cfg.attn.chunk)
            if mode == "prefill":
                ck, cv, cp = attn.update_cache_layer(
                    cache["k"], cache["v"], cache["kp"], k, v, positions)
                new_cache = dict(cache, k=ck, v=cv, kp=cp)
            else:
                new_cache = dict(cache)
        x = x + attn.attn_out(p["self"], o, cfg, cdt)

        # --- cross-attention
        h = norm(p["ln_cross"], x)
        hp = padded_heads(cfg)
        qx = linear(p["cross"]["wq"], h, cdt).reshape(
            b, x.shape[1], hp, hd)
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
        else:
            t_enc = enc_out.shape[1]
            xk = linear(p["cross"]["wk"], enc_out, cdt).reshape(
                b, t_enc, nkv, hd)
            xv = linear(p["cross"]["wv"], enc_out, cdt).reshape(
                b, t_enc, nkv, hd)
            if mode == "prefill":
                new_cache = dict(new_cache, xk=xk, xv=xv)
        xpos = jnp.broadcast_to(
            jnp.arange(xk.shape[1], dtype=jnp.int32)[None],
            (b, xk.shape[1]))
        if mode == "decode":
            o = attn.attend_decode(
                qx, xk, xv, xpos, idx,
                q_position=jnp.full((b,), 2 ** 30, jnp.int32))
        else:
            o = attn.attend_chunked(qx, xk, xv, idx, causal=False, window=0,
                                    chunk=cfg.attn.chunk)
        x = x + attn.attn_out(p["cross"], o, cfg, cdt)

        # --- mlp
        x = x + mlp(p["mlp"], norm(p["ln_mlp"], x), cfg.act, cdt)
        return x, new_cache

    if mode in ("prefill", "decode"):
        # serving: self-attn cache is a scan CARRY (in-place DUS); cross
        # K/V are xs at decode and collected ys at prefill
        K, V, KP = state.k, state.v, state.kpos
        L = cfg.n_layers
        xs = (params["dec_layers"], state.xk, state.xv,
              jnp.arange(L, dtype=jnp.int32))

        def serve_body(carry, per):
            x, K, V, KP = carry
            p_l, xk_l, xv_l, i = per
            c_l = {
                "k": jax.lax.dynamic_index_in_dim(K, i, 0, keepdims=False),
                "v": jax.lax.dynamic_index_in_dim(V, i, 0, keepdims=False),
                "kp": jax.lax.dynamic_index_in_dim(KP, i, 0, keepdims=False),
                "xk": xk_l, "xv": xv_l,
            }
            x, nc = layer(x, (p_l, c_l))
            K = jax.lax.dynamic_update_index_in_dim(K, nc["k"], i, 0)
            V = jax.lax.dynamic_update_index_in_dim(V, nc["v"], i, 0)
            KP = jax.lax.dynamic_update_index_in_dim(KP, nc["kp"], i, 0)
            return (x, K, V, KP), (nc["xk"], nc["xv"])

        (x, K, V, KP), (new_xk, new_xv) = jax.lax.scan(
            serve_body, (x, K, V, KP), xs)
        logits = _unembed_with({"ln_f": params["ln_f"],
                                "unembed": params["unembed"],
                                "embed": params["embed"]}, cfg, x)
        return logits, EncDecState(k=K, v=V, kpos=KP, xk=new_xk, xv=new_xv,
                                   pos=positions[:, -1] + 1)

    cache_tree = {"k": state.k, "v": state.v, "kp": state.kpos,
                  "xk": state.xk, "xv": state.xv}
    f = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable) \
        if (remat and mode == "train") else layer
    x, new_cache = jax.lax.scan(f, x, (params["dec_layers"], cache_tree))
    logits = _unembed_with({"ln_f": params["ln_f"],
                            "unembed": params["unembed"],
                            "embed": params["embed"]}, cfg, x)
    new_state = EncDecState(
        k=new_cache["k"], v=new_cache["v"], kpos=new_cache["kp"],
        xk=new_cache["xk"], xv=new_cache["xv"], pos=positions[:, -1] + 1)
    return logits, new_state


def encdec_state_init(cfg: ArchConfig, batch: int, budget: int, t_enc: int,
                      dtype=jnp.bfloat16) -> EncDecState:
    """Fresh decode state (used to lower serve_step without a prefill)."""
    L, nkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    return EncDecState(
        k=jnp.zeros((L, batch, budget, nkv, hd), dtype),
        v=jnp.zeros((L, batch, budget, nkv, hd), dtype),
        kpos=jnp.full((L, batch, budget), -1, jnp.int32),
        xk=jnp.zeros((L, batch, t_enc, nkv, hd), dtype),
        xv=jnp.zeros((L, batch, t_enc, nkv, hd), dtype),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def encdec_state_axes() -> EncDecState:
    return EncDecState(
        k=(None, "batch", "kvlen", "kv", None),
        v=(None, "batch", "kvlen", "kv", None),
        kpos=(None, "batch", "kvlen"),
        xk=(None, "batch", None, "kv", None),
        xv=(None, "batch", None, "kv", None),
        pos=("batch",),
    )


def build_encdec(cfg: ArchConfig):
    from .model import Model, cross_entropy

    def init(key):
        return encdec_init(key, cfg)

    def loss(params, batch, *, remat: bool = True):
        enc_out = encode(params, cfg, batch["frames"], remat=remat)
        logits, _ = _decoder(params, cfg, batch["tokens"], enc_out,
                             mode="train", state=None, remat=remat)
        total, n = cross_entropy(logits, batch["labels"], cfg.vocab)
        ce = total / jnp.maximum(n, 1)
        return ce, {"ce": ce, "aux": jnp.zeros(()), "tokens": n}

    def forward(params, batch):
        enc_out = encode(params, cfg, batch["frames"], remat=False)
        logits, _ = _decoder(params, cfg, batch["tokens"], enc_out,
                             mode="train", state=None, remat=False)
        return logits

    def prefill(params, batch, budget=None):
        enc_out = encode(params, cfg, batch["frames"], remat=False)
        logits, state = _decoder(params, cfg, batch["tokens"], enc_out,
                                 mode="prefill", state=None, remat=False,
                                 budget=budget)
        return logits[:, -1], state

    def decode_step(params, state, tokens):
        logits, state = _decoder(params, cfg, tokens, None, mode="decode",
                                 state=state, remat=False)
        return logits[:, -1], state

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, forward=forward)
