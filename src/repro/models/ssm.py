"""Recurrent / state-space blocks: xLSTM (mLSTM + sLSTM) and Mamba.

All three come in two forms that tests assert equivalent:
  * chunkwise-parallel (train/prefill): scan over chunks, matmul-heavy inside
    a chunk — the TPU-friendly formulation;
  * stepwise (decode): O(1)-state recurrence for one new token.

mLSTM follows the stabilised exponential-gating formulation of the xLSTM
paper (log-space gate cumulants + running max m); Mamba is the selective SSM
with ZOH discretisation, parallelised with an associative scan inside chunks.
The Mamba causal conv and xLSTM pre-projection convs are omitted (noted in
DESIGN.md §6) — they are local frontends orthogonal to the data-movement
study.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..core.config import ArchConfig
from ..distributed.sharding import Param, logical
from .layers import linear, linear_init, norm, norm_init, pad_to


# ===========================================================================
# mLSTM (matrix memory)
# ===========================================================================

class MLSTMState(NamedTuple):
    c: jax.Array      # (B, H, dk, dv) matrix memory
    n: jax.Array      # (B, H, dk)     normalizer
    m: jax.Array      # (B, H)         stabilizer (log-space running max)


def mlstm_init(key, cfg: ArchConfig, d_inner: int, n_heads: int):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(d_inner)
    return {
        "w_up": linear_init(ks[0], d, d_inner, ("embed", "heads")),
        "w_z": linear_init(ks[1], d, d_inner, ("embed", "heads")),
        # headwise (block-diagonal) q/k/v, as in the official xLSTM
        # LinearHeadwiseExpand — d_inner^2/H params instead of d_inner^2
        "w_q": Param(jax.random.normal(
            ks[2], (n_heads, d_inner // n_heads, d_inner // n_heads),
            jnp.float32) / math.sqrt(d_inner // n_heads),
            (None, None, None)),
        "w_k": Param(jax.random.normal(
            ks[3], (n_heads, d_inner // n_heads, d_inner // n_heads),
            jnp.float32) / math.sqrt(d_inner // n_heads),
            (None, None, None)),
        "w_v": Param(jax.random.normal(
            ks[4], (n_heads, d_inner // n_heads, d_inner // n_heads),
            jnp.float32) / math.sqrt(d_inner // n_heads),
            (None, None, None)),
        "w_i": Param(jax.random.normal(ks[5], (d_inner, n_heads),
                                       jnp.float32) * si, ("heads", None)),
        "w_f": Param(jax.random.normal(ks[6], (d_inner, n_heads),
                                       jnp.float32) * si, ("heads", None)),
        "b_i": Param(jnp.zeros((n_heads,), jnp.float32), (None,)),
        "b_f": Param(jnp.full((n_heads,), 3.0, jnp.float32), (None,)),
        "w_down": linear_init(ks[7], d_inner, d, ("heads", "embed")),
    }


def mlstm_state_init(batch: int, n_heads: int, dh: int) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        n=jnp.zeros((batch, n_heads, dh), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32),
    )


def _mlstm_chunk(carry: MLSTMState, qkv_if):
    """One chunk.  q,k,v: (B, H, L, dh); i_raw, f_raw: (B, H, L)."""
    q, k, v, i_raw, f_raw = qkv_if          # k arrives pre-scaled by 1/sqrt(dh)
    c0, n0, m0 = carry
    b, h, L, dh = q.shape
    lf = jax.nn.log_sigmoid(f_raw)                     # (B,H,L)
    bcum = jnp.cumsum(lf, axis=-1)                     # b_t
    a = i_raw
    # intra-chunk log weights  W[t,s] = b_t - b_s + a_s  (s <= t)
    w = bcum[..., :, None] - bcum[..., None, :] + a[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(tri, w, -1e30)
    db = bcum + m0[..., None]                          # inter decay + carry m
    m_t = jnp.maximum(jnp.max(w, axis=-1), db)         # (B,H,L)
    sc = jnp.einsum("bhtd,bhsd->bhts", q, k)
    s_mat = sc * jnp.exp(w - m_t[..., None])
    inter_w = jnp.exp(db - m_t)                        # (B,H,L)
    qc = jnp.einsum("bhtd,bhde->bhte", q, c0)          # q through carry C
    num = jnp.einsum("bhts,bhse->bhte", s_mat, v) + inter_w[..., None] * qc
    qn = jnp.sum(s_mat, axis=-1) + inter_w * jnp.einsum(
        "bhtd,bhd->bht", q, n0)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    h_out = num / denom[..., None]                     # (B,H,L,dh)
    # --- carry update
    b_L = bcum[..., -1]                                # (B,H)
    g = b_L[..., None] - bcum + a                      # (B,H,L) decay-to-end
    m_new = jnp.maximum(m0 + b_L, jnp.max(g, axis=-1))
    gw = jnp.exp(g - m_new[..., None])
    c_new = jnp.exp(m0 + b_L - m_new)[..., None, None] * c0 + jnp.einsum(
        "bhs,bhsd,bhse->bhde", gw, k, v)
    n_new = jnp.exp(m0 + b_L - m_new)[..., None] * n0 + jnp.einsum(
        "bhs,bhsd->bhd", gw, k)
    return MLSTMState(c_new, n_new, m_new), h_out


def mlstm_seq(q, k, v, i_raw, f_raw, state: MLSTMState, chunk: int):
    """q,k,v: (B, S, H, dh) fp32; gates (B, S, H).  Returns (h, new_state)."""
    b, s, h, dh = q.shape
    chunk = min(chunk, s)
    while s % chunk:       # largest divisor of s not exceeding the request
        chunk -= 1
    nc = s // chunk

    def to_chunks(x):
        # (B,S,H,...) -> (nc, B, H, L, ...)
        x = x.reshape(b, nc, chunk, h, *x.shape[3:])
        return jnp.moveaxis(x, (1, 3), (0, 2))
    xs = tuple(to_chunks(t) for t in (q, k, v, i_raw, f_raw))
    new_state, hs = jax.lax.scan(_mlstm_chunk, state, xs)
    hs = jnp.moveaxis(hs, (0, 2), (1, 3)).reshape(b, s, h, dh)
    return hs, new_state


def mlstm_step(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Single token: q,k,v (B, H, dh); gates (B, H)."""
    c0, n0, m0 = state                      # k arrives pre-scaled
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m0, i_raw)
    fw = jnp.exp(lf + m0 - m_new)[..., None]
    iw = jnp.exp(i_raw - m_new)[..., None]
    c = fw[..., None] * c0 + iw[..., None] * (k[..., :, None] * v[..., None, :])
    n = fw * n0 + iw * k
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    qn = jnp.einsum("bhd,bhd->bh", q, n)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    return num / denom[..., None], MLSTMState(c, n, m_new)


def mlstm_block(p, x, cfg: ArchConfig, state: MLSTMState, *, mode: str,
                n_heads: int, compute_dtype=jnp.bfloat16):
    """Full mLSTM block: up-proj -> heads -> cell -> gated down-proj.
    x: (B, S, d).  In decode mode S == 1."""
    b, s, d = x.shape
    up = linear(p["w_up"], x, compute_dtype)
    z = linear(p["w_z"], x, compute_dtype)
    d_inner = up.shape[-1]
    dh = d_inner // n_heads
    up_h = up.reshape(b, s, n_heads, dh)
    wq, wk, wv = (p[n].astype(compute_dtype) for n in ("w_q", "w_k", "w_v"))
    q = jnp.einsum("bshd,hde->bshe", up_h, wq).astype(jnp.float32)
    k = jnp.einsum("bshd,hde->bshe", up_h, wk).astype(jnp.float32) \
        / math.sqrt(dh)
    v = jnp.einsum("bshd,hde->bshe", up_h, wv).astype(jnp.float32)
    upf = up.astype(jnp.float32)
    i_raw = jnp.einsum("bsd,dh->bsh", upf, p["w_i"]) + p["b_i"]
    f_raw = jnp.einsum("bsd,dh->bsh", upf, p["w_f"]) + p["b_f"]
    if mode == "decode":
        h, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0],
                              f_raw[:, 0], state)
        h = h[:, None]
    else:
        h, state = mlstm_seq(q, k, v, i_raw, f_raw, state, cfg.ssm.chunk)
    h = h.reshape(b, s, d_inner).astype(compute_dtype) * jax.nn.silu(z)
    h = logical(h, "batch", None, "heads")
    out = linear(p["w_down"], h, compute_dtype)
    return logical(out, "batch", None, "residual"), state


# ===========================================================================
# sLSTM (scalar memory, exponential gating, block-diagonal recurrence)
# ===========================================================================

class SLSTMState(NamedTuple):
    c: jax.Array     # (B, H, dh)
    n: jax.Array     # (B, H, dh)
    m: jax.Array     # (B, H, dh)
    h: jax.Array     # (B, H, dh)


def slstm_init(key, cfg: ArchConfig, n_heads: int):
    d = cfg.d_model
    dh = d // n_heads
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "w_x": linear_init(ks[0], d, 4 * d, ("embed", "heads")),
        "r": Param(jax.random.normal(ks[1], (4, n_heads, dh, dh),
                                     jnp.float32) / math.sqrt(dh),
                   (None, "heads", None, None)),
        "b": Param(jnp.concatenate([
            jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))
        ]).astype(jnp.float32), ("heads",)),
        "w_up": linear_init(ks[2], d, 2 * d, ("embed", "mlp")),
        "w_down": linear_init(ks[3], d, d, ("mlp", "embed")),
    }


def slstm_state_init(batch: int, n_heads: int, dh: int) -> SLSTMState:
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return SLSTMState(z, z, jnp.full_like(z, -1e30), z)


def _slstm_cell(state: SLSTMState, xw, r):
    """xw: (B, 4, H, dh) pre-activations from the input; r: (4, H, dh, dh)."""
    c0, n0, m0, h0 = state
    rec = jnp.einsum("bhd,ghde->bghe", h0, r)          # (B,4,H,dh)
    zi, ii, fi, oi = [xw[:, g] + rec[:, g] for g in range(4)]
    m_new = jnp.maximum(fi + m0, ii)
    fw = jnp.exp(fi + m0 - m_new)
    iw = jnp.exp(ii - m_new)
    c = fw * c0 + iw * jnp.tanh(zi)
    n = fw * n0 + iw
    h = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, m_new, h), h


def slstm_block(p, x, cfg: ArchConfig, state: SLSTMState, *, mode: str,
                n_heads: int, compute_dtype=jnp.bfloat16):
    b, s, d = x.shape
    dh = d // n_heads
    xw = (linear(p["w_x"], x, compute_dtype).astype(jnp.float32)
          + p["b"]).reshape(b, s, 4, n_heads, dh)
    r = p["r"]
    if mode == "decode":
        state, h = _slstm_cell(state, xw[:, 0], r)
        hs = h[:, None]
    else:
        # unrolled time scan: XLA accumulates the recurrent-weight grads
        # locally across unrolled steps instead of emitting a per-timestep
        # cross-replica all-reduce in the backward pass.  unroll=32 is the
        # sweet spot: 128 left the wire UNCHANGED while inflating compile
        # time 8x and HBM +20% (XLA stops coalescing the dR tuple beyond
        # ~32) — measured and recorded in EXPERIMENTS.md SSPerf.
        unroll = 32 if s % 32 == 0 else 1
        state, hs = jax.lax.scan(
            lambda st, xt: _slstm_cell(st, xt, r),
            state, jnp.moveaxis(xw, 1, 0), unroll=unroll)
        hs = jnp.moveaxis(hs, 0, 1)                    # (B,S,H,dh)
    hs = hs.reshape(b, s, d).astype(compute_dtype)
    # post-cell feed-forward (GEGLU, pf ~ 4/3 in the paper; we use 2x then gate)
    up = linear(p["w_up"], hs, compute_dtype)
    u1, u2 = jnp.split(up, 2, axis=-1)
    out = linear(p["w_down"], jax.nn.gelu(u1) * u2, compute_dtype)
    return logical(out, "batch", None, "residual"), state


# ===========================================================================
# Mamba (selective SSM), hymba's parallel head
# ===========================================================================

class MambaState(NamedTuple):
    s: jax.Array     # (B, d_inner, N)


def mamba_init(key, cfg: ArchConfig, d_inner: int):
    d = cfg.d_model
    n = cfg.ssm.d_state
    dt_rank = max(d // 16, 8)
    ks = jax.random.split(key, 7)
    return {
        "w_in": linear_init(ks[0], d, d_inner, ("embed", "heads")),
        "w_z": linear_init(ks[1], d, d_inner, ("embed", "heads")),
        "w_bc": linear_init(ks[2], d, 2 * n, ("embed", None)),
        "w_dt1": linear_init(ks[3], d, dt_rank, ("embed", None)),
        "w_dt2": linear_init(ks[4], dt_rank, d_inner, (None, "heads")),
        "dt_bias": Param(jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(
                ks[5], (d_inner,), minval=math.log(1e-3),
                maxval=math.log(1e-1))), 1e-4, 1e-1))).astype(jnp.float32),
            ("heads",)),
        # Mamba-2 style scalar decay per channel (enables the SSD chunk
        # formulation — see _mamba_ssd_chunk)
        "a_log": Param(jnp.log(jnp.linspace(1.0, float(n), d_inner)
                               ).astype(jnp.float32), ("heads",)),
        "d_skip": Param(jnp.ones((d_inner,), jnp.float32), ("heads",)),
        "w_out": linear_init(ks[6], d_inner, d, ("heads", "embed")),
    }


def mamba_state_init(batch: int, d_inner: int, n: int) -> MambaState:
    return MambaState(jnp.zeros((batch, d_inner, n), jnp.float32))


def _mamba_scan_chunk(carry, xs):
    """Associative scan inside a chunk.  a_bar, bx: (B, L, D, N).
    (Reference path: materialises (B, L, D, N) at every ladder level —
    kept for tests; the SSD path below is the production formulation.)"""
    a_bar, bx = xs
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    a_cum, b_cum = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    s = b_cum + a_cum * carry[:, None]                 # (B,L,D,N)
    return s[:, -1], s


def _mamba_ssd_chunk(carry, xs):
    """Mamba-2 SSD chunk: y computed via the (L, L) segment-sum decay matrix
    without EVER materialising per-step states — the §Perf hymba hillclimb
    (the associative-scan ladder was 100x memory-bound on the dry-run).

    la: (B,L,D) log-decay;  du: (B,L,D) Δ*u;  b_t, c_t: (B,L,N).
    carry: (B,D,N).  Returns (new_carry, y (B,L,D))."""
    la, du, b_t, c_t = xs
    cum = jnp.cumsum(la, axis=1)                       # (B,L,D) inclusive
    # segment decay M[c,t,s] = exp(cum_t - cum_s) for s <= t (log args <= 0)
    diff = cum[:, :, None, :] - cum[:, None, :, :]     # (B,T,S,D)
    L = la.shape[1]
    tri = jnp.tril(jnp.ones((L, L), bool))
    m = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("btn,bsn->bts", c_t, b_t)          # (B,T,S)
    y = jnp.einsum("btsd,bts,bsd->btd", m, cb, du)
    # inter-chunk: y += C_t . (exp(cum_t) * s0)
    y += jnp.einsum("btn,bdn,btd->btd", c_t, carry, jnp.exp(cum))
    # carry update: s_new = sum_s exp(cum_L - cum_s) du_s B_s + exp(cum_L) s0
    w_end = jnp.exp(cum[:, -1:, :] - cum)              # (B,L,D)
    s_new = jnp.einsum("bld,bln,bld->bdn", w_end, b_t, du) \
        + jnp.exp(cum[:, -1])[..., None] * carry
    return s_new, y


def mamba_apply(p, x, cfg: ArchConfig, state: MambaState, *, mode: str,
                compute_dtype=jnp.bfloat16):
    """x: (B, S, d) -> ((B, S, d), new_state)."""
    b, s, d = x.shape
    nst = cfg.ssm.d_state
    u = linear(p["w_in"], x, compute_dtype).astype(jnp.float32)  # (B,S,D)
    z = linear(p["w_z"], x, compute_dtype)
    bc = linear(p["w_bc"], x, compute_dtype).astype(jnp.float32)
    b_t, c_t = jnp.split(bc, 2, axis=-1)               # (B,S,N)
    dt = jax.nn.softplus(
        linear(p["w_dt2"], linear(p["w_dt1"], x, compute_dtype),
               compute_dtype).astype(jnp.float32) + p["dt_bias"])  # (B,S,D)
    a = -jnp.exp(p["a_log"])                           # (D,) scalar decay
    la = dt * a                                        # (B,S,D) log decay
    du = dt * u                                        # (B,S,D)

    if mode == "decode":
        a_bar = jnp.exp(la[:, 0])                      # (B,D)
        new_s = a_bar[..., None] * state.s \
            + (du[:, 0])[..., None] * b_t[:, 0][:, None, :]
        y = jnp.einsum("bdn,bn->bd", new_s, c_t[:, 0])[:, None]
        new_state = MambaState(new_s)
    else:
        chunk = min(cfg.ssm.chunk, s)
        while s % chunk:   # largest divisor of s not exceeding the request
            chunk -= 1
        nc = s // chunk
        resh = lambda t: jnp.moveaxis(
            t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)
        # checkpoint the chunk: the (T, S, D) segment matrix is recomputed
        # in the backward instead of being residual-stacked over all chunks
        # (a 13 GB/chip save on hymba train_4k)
        body = jax.checkpoint(
            _mamba_ssd_chunk,
            policy=jax.checkpoint_policies.nothing_saveable)
        carry, y = jax.lax.scan(
            body, state.s, (resh(la), resh(du), resh(b_t), resh(c_t)))
        y = jnp.moveaxis(y, 0, 1).reshape(b, s, -1)
        new_state = MambaState(carry)

    y = y + p["d_skip"] * u
    y = (y.astype(compute_dtype)) * jax.nn.silu(z)
    y = logical(y, "batch", None, "heads")
    out = linear(p["w_out"], y, compute_dtype)
    return logical(out, "batch", None, "residual"), new_state
