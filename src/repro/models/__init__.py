"""Model stack: one parameterized transformer covering the 10 assigned
architectures (dense / moe / ssm / hybrid / encdec / vlm)."""
from .model import Model, build_model, cross_entropy

__all__ = ["Model", "build_model", "cross_entropy"]
