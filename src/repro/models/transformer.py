"""Decoder-only transformer assembly covering dense / moe / ssm / hybrid /
vlm families with one scan-over-layers implementation.

Modes:
  train    full sequence, teacher forcing, remat-inside-scan
  prefill  full sequence, returns a decode state (KV caches + SSM states)
  decode   one token against the state

Layer heterogeneity (xlstm's mLSTM/sLSTM mix, hymba's global/local attention
mix) is expressed as per-layer flag arrays threaded through the scan, so the
whole depth still compiles as ONE scanned layer (critical for compile time at
95 layers).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..distributed.sharding import Param, logical
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (embed, embed_init, linear, linear_init, mlp, mlp_init,
                     norm, norm_init, padded_heads, padded_vocab)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "hybrid"):
        p["ln_attn"] = norm_init(cfg.d_model, cfg.norm)
        p["attn"] = attn.attn_init(ks[0], cfg)
        if not cfg.parallel_residual:
            p["ln_mlp"] = norm_init(cfg.d_model, cfg.norm)
        if cfg.moe.enabled:
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        elif cfg.d_ff > 0:
            p["mlp"] = mlp_init(ks[1], cfg)
    if fam == "hybrid":
        d_inner = cfg.ssm.expand * cfg.d_model
        p["mamba"] = ssm_mod.mamba_init(ks[2], cfg, d_inner)
    if fam == "ssm":
        p["ln"] = norm_init(cfg.d_model, cfg.norm)
        d_inner = cfg.ssm.expand * cfg.d_model
        p["mlstm"] = ssm_mod.mlstm_init(ks[3], cfg, d_inner, cfg.n_heads)
    return p


def _slstm_layer_init(key, cfg: ArchConfig):
    return {"ln_s": norm_init(cfg.d_model, cfg.norm),
            "slstm": ssm_mod.slstm_init(key, cfg, cfg.n_heads)}


def ssm_layer_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_mlstm, n_slstm) for the xLSTM 7:1-style interleave."""
    L = cfg.n_layers
    if cfg.family != "ssm" or cfg.ssm.slstm_every <= 0:
        return L, 0
    n_s = L // cfg.ssm.slstm_every
    return L - n_s, n_s


def layer_flags(cfg: ArchConfig) -> Dict[str, np.ndarray]:
    """Static per-layer flag arrays threaded through the scan."""
    L = cfg.n_layers
    flags: Dict[str, np.ndarray] = {}
    if cfg.family == "hybrid":
        # hymba: global (full) attention on first / middle / last layer
        g = np.zeros((L,), np.bool_)
        g[[0, L // 2, L - 1]] = True
        flags["global_attn"] = g
    return flags


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

class State(NamedTuple):
    """Stacked-over-layers decode state.  Unused fields hold size-0 arrays so
    the pytree structure is uniform across families."""
    k: jax.Array
    v: jax.Array
    kpos: jax.Array
    mlstm_c: jax.Array
    mlstm_n: jax.Array
    mlstm_m: jax.Array
    slstm: jax.Array          # (4, L, B, H, dh): c, n, m, h
    mamba: jax.Array          # (L, B, D, N)
    pos: jax.Array            # (B,) next absolute position


def _z(*shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def init_state(cfg: ArchConfig, batch: int, budget: int,
               dtype=jnp.bfloat16) -> State:
    L, d = cfg.n_layers, cfg.d_model
    has_attn = cfg.family in ("dense", "moe", "vlm", "hybrid", "encdec")
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    w = budget if has_attn else 0
    if cfg.family == "ssm":
        n_m, n_s = ssm_layer_counts(cfg)
        dh = cfg.ssm.expand * d // cfg.n_heads
        dhs = d // cfg.n_heads
        ml_c = _z(n_m, batch, cfg.n_heads, dh, dh)
        ml_n = _z(n_m, batch, cfg.n_heads, dh)
        ml_m = jnp.full((n_m, batch, cfg.n_heads), -1e30, jnp.float32)
        sl = _z(4, n_s, batch, cfg.n_heads, dhs).at[2].set(-1e30)
    else:
        ml_c = _z(L, 0, 0, 0, 0)
        ml_n = _z(L, 0, 0)
        ml_m = _z(L, 0, 0)
        sl = _z(4, L, 0, 0, 0)
    if cfg.family == "hybrid":
        mam = _z(L, batch, cfg.ssm.expand * d, cfg.ssm.d_state)
    else:
        mam = _z(L, 0, 0, 0)
    return State(
        k=_z(L, batch, w, nkv, hd, dtype=dtype),
        v=_z(L, batch, w, nkv, hd, dtype=dtype),
        kpos=jnp.full((L, batch, w), -1, jnp.int32),
        mlstm_c=ml_c, mlstm_n=ml_n, mlstm_m=ml_m, slstm=sl, mamba=mam,
        pos=jnp.zeros((batch,), jnp.int32),
    )


def state_axes() -> State:
    """Logical axes for sharding the decode state."""
    return State(
        k=(None, "batch", "kvlen", "kv", None),
        v=(None, "batch", "kvlen", "kv", None),
        kpos=(None, "batch", "kvlen"),
        mlstm_c=(None, "batch", None, None, None),
        mlstm_n=(None, "batch", None, None),
        mlstm_m=(None, "batch", None),
        slstm=(None, None, "batch", None, None),
        mamba=(None, "batch", "heads", None),
        pos=("batch",),
    )


def _constrain_state(st: State) -> State:
    ax = state_axes()
    return State(*[logical(v, *a) for v, a in zip(st, ax)])


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------

def _attn_block(p, x, cfg, positions, mode, cache, global_flag, cdt):
    """Returns (out, new_cache).  cache = (k, v, kpos) single-layer or None."""
    window = cfg.attn.window
    hp = padded_heads(cfg)
    idx_map = attn.kv_index_map(cfg.n_heads, cfg.n_kv_heads, hp)
    q, k, v = attn.qkv_project(p, x, cfg, positions, cdt)
    new_cache = cache
    if mode == "decode":
        ck, cv, cpos = cache
        ck, cv, cpos = attn.update_cache_layer(ck, cv, cpos, k, v, positions)
        out_h = attn.attend_decode(
            q, ck, cv, cpos, idx_map, q_position=positions[:, 0],
            window=window, global_flag=global_flag)
        new_cache = (ck, cv, cpos)
    else:
        causal = cfg.attn.kind != "none"
        out_h = attn.attend_chunked(
            q, k, v, idx_map, causal=causal, window=window,
            chunk=cfg.attn.chunk, global_flag=global_flag)
        if mode == "prefill":
            ck, cv, cpos = cache
            w = ck.shape[1]
            s = k.shape[1]
            if s >= w:
                tail = slice(s - w, s)
                ck, cv, cpos = attn.update_cache_layer(
                    ck, cv, cpos, k[:, tail], v[:, tail],
                    positions[:, tail])
            else:
                ck, cv, cpos = attn.update_cache_layer(
                    ck, cv, cpos, k, v, positions)
            new_cache = (ck, cv, cpos)
    out = attn.attn_out(p, out_h, cfg, cdt)
    return out, new_cache


def _ffn_residual(p, x, h, attn_out, cfg: ArchConfig, cdt):
    """Residual + FFN tail shared by every attention family (dense / moe /
    vlm), in both the dense-cache and paged decode paths.  ``h`` is the
    pre-attention normed input (reused by parallel-residual archs)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_residual:
        if cfg.moe.enabled:
            ff, aux = moe_mod.moe_apply(p["moe"], h, cfg, cdt)
        else:
            ff = mlp(p["mlp"], h, cfg.act, cdt)
        x = x + attn_out + ff
    else:
        x = x + attn_out
        h2 = norm(p["ln_mlp"], x)
        if cfg.moe.enabled:
            ff, aux = moe_mod.moe_apply(p["moe"], h2, cfg, cdt)
        else:
            ff = mlp(p["mlp"], h2, cfg.act, cdt)
        x = x + ff
    return logical(x, "batch", "seq", "residual"), aux


def make_layer_fn(cfg: ArchConfig, mode: str):
    cdt = jnp.dtype(cfg.dtype)

    def layer(x, per):
        p, cache, flags = per
        aux = jnp.zeros((), jnp.float32)
        positions = flags["positions"]
        fam = cfg.family

        if fam == "ssm":
            # mLSTM-only layer; sLSTM layers run in the interleaved stack
            # (see _ssm_forward) — no lax.cond, so cost attribution is exact
            st_m = ssm_mod.MLSTMState(cache["mc"], cache["mn"], cache["mm"])
            h = norm(p["ln"], x)
            out, st = ssm_mod.mlstm_block(
                p["mlstm"], h, cfg, st_m, mode=mode,
                n_heads=cfg.n_heads, compute_dtype=cdt)
            x = x + out
            new_cache = dict(cache, mc=st.c, mn=st.n, mm=st.m)
            return x, (new_cache, aux)

        # families with attention
        gflag = flags.get("global_attn")
        h = norm(p["ln_attn"], x)
        attn_out, new_kv = _attn_block(
            p["attn"], h, cfg, positions, mode,
            (cache["k"], cache["v"], cache["kp"]), gflag, cdt)
        new_cache = dict(cache, k=new_kv[0], v=new_kv[1], kp=new_kv[2])

        if fam == "hybrid":
            st = ssm_mod.MambaState(cache["mb"])
            mamba_out, st2 = ssm_mod.mamba_apply(
                p["mamba"], h, cfg, st, mode=mode, compute_dtype=cdt)
            mixed = (attn_out + mamba_out) * 0.5
            new_cache["mb"] = st2.s
            x = x + mixed
            h2 = norm(p["ln_mlp"], x)
            x = x + mlp(p["mlp"], h2, cfg.act, cdt)
            return x, (new_cache, aux)

        x, aux = _ffn_residual(p, x, h, attn_out, cfg, cdt)
        return x, (new_cache, aux)

    return layer


def _cache_tree(cfg: ArchConfig, st: State):
    """Per-layer cache dict (leading L dim) fed to the scan as xs."""
    return {"k": st.k, "v": st.v, "kp": st.kpos,
            "mc": st.mlstm_c, "mn": st.mlstm_n, "mm": st.mlstm_m,
            "sl": jnp.moveaxis(st.slstm, 0, 1),   # (L,4,...)
            "mb": st.mamba}


def _state_from_cache(cfg: ArchConfig, cache, pos) -> State:
    return State(
        k=cache["k"], v=cache["v"], kpos=cache["kp"],
        mlstm_c=cache["mc"], mlstm_n=cache["mn"], mlstm_m=cache["mm"],
        slstm=jnp.moveaxis(cache["sl"], 1, 0),
        mamba=cache["mb"], pos=pos)


def _flags_tree(cfg: ArchConfig, positions):
    """Per-layer flags; ``positions`` is shared (broadcast to every layer)."""
    f = layer_flags(cfg)
    out = {k: jnp.asarray(v) for k, v in f.items()}
    return out


# ---------------------------------------------------------------------------
# Full model init / apply
# ---------------------------------------------------------------------------

def stack_init(fn, key, n: int, cfg: ArchConfig):
    """vmap-stack ``n`` layers of ``fn(key, cfg)``; annotations (strings)
    cannot pass through vmap, so init strips them (capturing the static axes
    tree as a tracing side-channel) and re-annotates after."""
    from ..distributed.sharding import split_tree
    axes_box = {}

    def stripped(k):
        vals, axes = split_tree(fn(k, cfg))
        axes_box["axes"] = axes
        return vals

    stacked_vals = jax.vmap(stripped)(jax.random.split(key, n))
    return jax.tree.map(
        lambda arr, ax: Param(arr, (None,) + ax),
        stacked_vals, axes_box["axes"])


def transformer_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 5)
    L = cfg.n_layers
    n_m, n_s = ssm_layer_counts(cfg)
    stacked = stack_init(_layer_init, ks[0], n_m if cfg.family == "ssm"
                         else L, cfg)
    p = {
        "embed": embed_init(ks[1], padded_vocab(cfg), cfg.d_model),
        "layers": stacked,
        "ln_f": norm_init(cfg.d_model, cfg.norm),
    }
    if n_s > 0:
        p["slstm_layers"] = stack_init(_slstm_layer_init, ks[4], n_s, cfg)
    if not cfg.tie_embeddings:
        p["unembed"] = linear_init(ks[2], cfg.d_model, padded_vocab(cfg),
                                   ("embed", "vocab"))
    if cfg.n_patches > 0:
        p["patch_proj"] = linear_init(ks[3], cfg.d_model, cfg.d_model,
                                      ("embed", "embed2"))
    return p


def sinusoid(positions, d: int):
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_inputs(p, cfg: ArchConfig, tokens, patches, positions, cdt):
    x = embed(p["embed"], tokens, cdt)
    if cfg.n_patches > 0 and patches is not None:
        pe = linear(p["patch_proj"], patches.astype(cdt), cdt)
        x = jnp.concatenate([pe, x], axis=1)
    if cfg.attn.rope_theta == 0:
        x = x + sinusoid(positions, cfg.d_model).astype(cdt)
    return logical(x, "batch", "seq", "residual")


def unembed(p, cfg: ArchConfig, x):
    xf = norm(p["ln_f"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", xf.astype(jnp.float32),
                            p["embed"]["emb"].astype(jnp.float32))
    else:
        w = p["unembed"]["w"]
        logits = jnp.einsum("bsd,dv->bsv", xf.astype(jnp.float32),
                            w.astype(jnp.float32))
    # mask vocab-padding slots (vocab padded up for TP divisibility)
    vp = logits.shape[-1]
    if vp != cfg.vocab:
        logits = jnp.where(jnp.arange(vp) < cfg.vocab, logits, -1e30)
    return logical(logits, "batch", "seq", "vocab")


def _ssm_forward(params, cfg: ArchConfig, x, state: State, *, mode: str,
                 positions, remat: bool):
    """Interleaved xLSTM stack: groups of (every-1) mLSTM layers + 1 sLSTM.
    The group scan doubles as hierarchical remat (group inputs saved)."""
    cdt = jnp.dtype(cfg.dtype)
    every = cfg.ssm.slstm_every
    L = cfg.n_layers
    n_m, n_s = ssm_layer_counts(cfg)
    layer_fn = make_layer_fn(cfg, mode)
    do_ckpt = remat and mode == "train"
    if do_ckpt:
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def mlstm_scan(x, stacks):
        def body(x, per):
            p_l, (mc, mn, mm) = per
            cache = {"mc": mc, "mn": mn, "mm": mm}
            x, (nc, _) = layer_fn(x, (p_l, cache, {"positions": positions}))
            return x, (nc["mc"], nc["mn"], nc["mm"])
        return jax.lax.scan(body, x, stacks)

    def slstm_apply(x, p_l, sl):
        st = ssm_mod.SLSTMState(sl[0], sl[1], sl[2], sl[3])
        h = norm(p_l["ln_s"], x)
        out, st2 = ssm_mod.slstm_block(
            p_l["slstm"], h, cfg, st, mode=mode, n_heads=cfg.n_heads,
            compute_dtype=cdt)
        return x + out, jnp.stack(list(st2))
    if do_ckpt:
        slstm_apply = jax.checkpoint(
            slstm_apply, policy=jax.checkpoint_policies.nothing_saveable)

    m_states = (state.mlstm_c, state.mlstm_n, state.mlstm_m)
    if n_s == 0:
        x, new_m = mlstm_scan(x, (params["layers"], m_states))
        new_sl = state.slstm
    else:
        groups = n_s
        per_g = every - 1
        regroup = lambda t: t.reshape(groups, per_g, *t.shape[1:])
        pm = jax.tree.map(regroup, params["layers"])
        sm = jax.tree.map(regroup, m_states)
        sl = jnp.moveaxis(state.slstm, 1, 0)            # (n_s, 4, ...)

        def group_body(x, per):
            pm_g, sm_g, ps_g, sl_g = per
            x, new_sm = mlstm_scan(x, (pm_g, sm_g))
            x, new_sl = slstm_apply(x, ps_g, sl_g)
            return x, (new_sm, new_sl)

        if do_ckpt:
            group_body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (new_m_g, new_sl_g) = jax.lax.scan(
            group_body, x, (pm, sm, params["slstm_layers"], sl))
        new_m = jax.tree.map(
            lambda t: t.reshape(n_m, *t.shape[2:]), new_m_g)
        new_sl = jnp.moveaxis(new_sl_g, 0, 1)           # (4, n_s, ...)

    new_state = State(
        k=state.k, v=state.v, kpos=state.kpos,
        mlstm_c=new_m[0], mlstm_n=new_m[1], mlstm_m=new_m[2],
        slstm=new_sl, mamba=state.mamba, pos=positions[:, -1] + 1)
    return x, new_state


def _remat_group(L: int) -> int:
    """Largest divisor of L not exceeding ~sqrt(L) (hierarchical remat)."""
    limit = max(2, int(math.isqrt(L)) + 1)
    best = 1
    for g in range(2, limit + 1):
        if L % g == 0:
            best = g
    return best if L // best > 1 else 1


def forward(params, cfg: ArchConfig, tokens, *, patches=None,
            mode: str = "train", state: Optional[State] = None,
            remat: bool = True, budget: Optional[int] = None):
    """Returns (logits, new_state_or_None, aux_loss).  ``budget`` sets the
    KV-cache length a prefill allocates (>= prompt + planned new tokens)."""
    cdt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    if mode == "decode":
        assert state is not None
        positions = state.pos[:, None]                 # (B, 1)
    else:
        s_tok = tokens.shape[1]
        extra = cfg.n_patches if patches is not None else 0
        positions = jnp.broadcast_to(
            jnp.arange(s_tok + extra, dtype=jnp.int32)[None], (b, s_tok + extra))
    x = _embed_inputs(params, cfg, tokens, patches, positions, cdt)

    layer_fn = make_layer_fn(cfg, mode)
    if remat and mode == "train":
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    if state is None:
        # train needs no KV budget (fresh k/v per layer); prefill caches at
        # least the prompt (callers pass headroom for the decode phase)
        w = 0 if mode == "train" else max(budget or 0, x.shape[1])
        state = init_state(cfg, b, budget=w, dtype=cdt)

    if cfg.family == "ssm":
        x, new_state = _ssm_forward(params, cfg, x, state, mode=mode,
                                    positions=positions, remat=remat)
        logits = unembed(params, cfg, x)
        if mode != "train":
            new_state = _constrain_state(new_state)
        return logits, new_state, jnp.zeros((), jnp.float32)

    flags = _flags_tree(cfg, positions)
    L = cfg.n_layers

    if mode in ("prefill", "decode"):
        # serving: the KV cache is a scan CARRY updated in place (XLA's
        # in-loop dynamic-update-slice aliasing) — stacking it through
        # scan xs/ys would hold 2-3 cache-sized temps per step
        K, V, KP = state.k, state.v, state.kpos
        xs = (params["layers"], state.mamba, flags,
              jnp.arange(L, dtype=jnp.int32))

        def serve_body(carry, per):
            x, K, V, KP = carry
            p_l, mb_l, f_l, i = per
            c_l = {
                "k": jax.lax.dynamic_index_in_dim(K, i, 0, keepdims=False),
                "v": jax.lax.dynamic_index_in_dim(V, i, 0, keepdims=False),
                "kp": jax.lax.dynamic_index_in_dim(KP, i, 0, keepdims=False),
                "mb": mb_l,
            }
            f_l = dict(f_l, positions=positions)
            x, (nc, aux) = layer_fn(x, (p_l, c_l, f_l))
            K = jax.lax.dynamic_update_index_in_dim(K, nc["k"], i, 0)
            V = jax.lax.dynamic_update_index_in_dim(V, nc["v"], i, 0)
            KP = jax.lax.dynamic_update_index_in_dim(KP, nc["kp"], i, 0)
            return (x, K, V, KP), (nc["mb"], aux)

        (x, K, V, KP), (new_mb, auxs) = jax.lax.scan(
            serve_body, (x, K, V, KP), xs)
        logits = unembed(params, cfg, x)
        new_state = State(
            k=K, v=V, kpos=KP,
            mlstm_c=state.mlstm_c, mlstm_n=state.mlstm_n,
            mlstm_m=state.mlstm_m, slstm=state.slstm, mamba=new_mb,
            pos=positions[:, -1] + 1)
        return logits, _constrain_state(new_state), jnp.sum(auxs)

    # training path
    cache = _cache_tree(cfg, state)

    def scan_body(x, per_layer):
        p_l, c_l, f_l = per_layer
        f_l = dict(f_l, positions=positions)
        return layer_fn(x, (p_l, c_l, f_l))

    g = _remat_group(L) if remat else 1
    if g > 1:
        # hierarchical (sqrt-L) remat: only L/g group-boundary activations
        # are saved; layers inside a group recompute from the group input
        # (deepseek-67b train: 6.1 GB of saved layer inputs -> ~1.2 GB).
        def regroup(t):
            return t.reshape(L // g, g, *t.shape[1:])
        xs = jax.tree.map(regroup, (params["layers"], cache, flags))

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def group_body(x, per_group):
            return jax.lax.scan(scan_body, x, per_group)

        x, (new_cache, auxs) = jax.lax.scan(group_body, x, xs)
        auxs = auxs.reshape(L)
    else:
        x, (new_cache, auxs) = jax.lax.scan(
            scan_body, x, (params["layers"], cache, flags))
    logits = unembed(params, cfg, x)
    return logits, None, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Paged decode (continuous batching over a block-arena KV cache)
# ---------------------------------------------------------------------------

#: families the paged decode path supports (attention-only decode state; the
#: recurrent families carry extra per-layer state a block arena doesn't hold)
PAGED_FAMILIES = ("dense", "moe", "vlm")


class PagedState(NamedTuple):
    """Block-arena KV cache shared by all batch slots.  ``k``/``v``:
    (L, n_blocks, block_len, KV, hd); ``pos``: (n_blocks, block_len)
    absolute position of each row (-1 = empty).  Positions are identical
    across layers, so one plane serves the whole stack.  Block 0 is the
    scratch block inactive slots write into (see models.attention)."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array


def init_paged_state(cfg: ArchConfig, n_blocks: int, block_len: int,
                     dtype=None) -> PagedState:
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged decode supports families {PAGED_FAMILIES}, "
            f"not {cfg.family!r}")
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    shape = (cfg.n_layers, n_blocks, block_len, cfg.n_kv_heads, cfg.head_dim_)
    return PagedState(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                      pos=jnp.full((n_blocks, block_len), -1, jnp.int32))


def forward_paged_decode(params, cfg: ArchConfig, tokens, paged: PagedState,
                         block_table, slot_pos):
    """One decode step for ``B`` independent slots over the block arena.

    tokens: (B, 1) int32 (each slot's previous token); block_table: (B, MB)
    int32 block ids, -1 = unused; slot_pos: (B,) each slot's next absolute
    position.  Unlike the dense-cache decode, slots need NOT share a
    position — each writes at its own (block, row) and attends only rows
    whose gathered position is in [0, its own position].  Returns
    (last-token logits, new PagedState)."""
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged decode supports families {PAGED_FAMILIES}, "
            f"not {cfg.family!r}")
    cdt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    bl = paged.pos.shape[1]
    positions = slot_pos[:, None]                       # (B, 1)
    x = _embed_inputs(params, cfg, tokens, None, positions, cdt)

    # this step's write target per slot; inactive slots (table entry -1)
    # clamp to the scratch block 0, whose rows are never attended
    blk = jnp.take_along_axis(block_table,
                              (slot_pos // bl)[:, None], axis=1)[:, 0]
    blk = jnp.maximum(blk, 0)
    off = slot_pos % bl
    pos_blocks = paged.pos.at[blk, off].set(slot_pos)

    hp = padded_heads(cfg)
    idx_map = attn.kv_index_map(cfg.n_heads, cfg.n_kv_heads, hp)
    L = cfg.n_layers

    def body(carry, per):
        x, K, V = carry
        p_l, i = per
        k_l = jax.lax.dynamic_index_in_dim(K, i, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(V, i, 0, keepdims=False)
        h = norm(p_l["ln_attn"], x)
        q, k_new, v_new = attn.qkv_project(p_l["attn"], h, cfg, positions,
                                           cdt)
        k_l, v_l = attn.append_paged_layer(k_l, v_l, k_new, v_new, blk, off)
        out_h = attn.attend_paged(
            q, k_l, v_l, pos_blocks, block_table, idx_map,
            q_position=slot_pos, window=cfg.attn.window)
        attn_o = attn.attn_out(p_l["attn"], out_h, cfg, cdt)
        x, aux = _ffn_residual(p_l, x, h, attn_o, cfg, cdt)
        K = jax.lax.dynamic_update_index_in_dim(K, k_l, i, 0)
        V = jax.lax.dynamic_update_index_in_dim(V, v_l, i, 0)
        return (x, K, V), aux

    (x, K, V), _ = jax.lax.scan(
        body, (x, paged.k, paged.v),
        (params["layers"], jnp.arange(L, dtype=jnp.int32)))
    logits = unembed(params, cfg, x)
    return logits[:, -1], PagedState(k=K, v=V, pos=pos_blocks)


def forward_paged_chunk(params, cfg: ArchConfig, tokens, paged: PagedState,
                        block_table, start, n_real):
    """One prefill CHUNK for a single slot over the block arena.

    tokens: (1, C) int32 — rows ``[0, n_real)`` are the real chunk, the
    rest is pow2-bucket padding; block_table: (1, MB) the slot's table
    (-1 = unused); start: () int32 the chunk's first absolute row;
    n_real: () int32 real-row count (1 <= n_real <= C).  Writes the real
    rows' K/V into the slot's blocks (pad rows land in scratch block 0
    with position -1, so they are never attended) and returns
    (logits of row start+n_real-1, shape (1, vocab_p), new PagedState).

    Numerics: K/V/FFN are per-row and attention goes through
    ``attend_prefix``'s full masked softmax over the gathered MB*BL view,
    so row values do not depend on the chunk decomposition — chunked,
    shared-prefix, and solo prefill agree bit-for-bit (the equivalence
    tests' anchor).  Note this is a *different* decomposition from the
    monolithic ``prefill`` path's online-softmax ``attend_chunked``, so
    chunked mode is only bit-comparable to chunked-mode oracles."""
    if cfg.family not in PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged chunk prefill supports families {PAGED_FAMILIES}, "
            f"not {cfg.family!r}")
    cdt = jnp.dtype(cfg.dtype)
    c = tokens.shape[1]
    bl = paged.pos.shape[1]
    mb = block_table.shape[1]
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = start + offs                            # (C,)
    valid = offs < n_real
    pos_q = positions[None, :]                          # (1, C)
    x = _embed_inputs(params, cfg, tokens, None, pos_q, cdt)

    # per-row write targets; pad rows clamp to scratch block 0 (their
    # position row is forced to -1, so last-wins scatter races among pad
    # rows at (0, 0) are harmless)
    bt = block_table[0]                                 # (MB,)
    bidx = jnp.clip(positions // bl, 0, mb - 1)
    blk = jnp.where(valid, jnp.maximum(bt[bidx], 0), 0)
    off = jnp.where(valid, positions % bl, 0)
    pos_blocks = paged.pos.at[blk, off].set(
        jnp.where(valid, positions, -1))

    hp = padded_heads(cfg)
    idx_map = attn.kv_index_map(cfg.n_heads, cfg.n_kv_heads, hp)
    L = cfg.n_layers

    def body(carry, per):
        x, K, V = carry
        p_l, i = per
        k_l = jax.lax.dynamic_index_in_dim(K, i, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(V, i, 0, keepdims=False)
        h = norm(p_l["ln_attn"], x)
        q, k_new, v_new = attn.qkv_project(p_l["attn"], h, cfg, pos_q, cdt)
        k_l = k_l.at[blk, off].set(k_new[0])
        v_l = v_l.at[blk, off].set(v_new[0])
        kd, vd, pd = attn.gather_paged_view(k_l, v_l, pos_blocks,
                                            block_table)
        out_h = attn.attend_prefix(q, kd, vd, pd, idx_map,
                                   q_positions=pos_q,
                                   window=cfg.attn.window)
        attn_o = attn.attn_out(p_l["attn"], out_h, cfg, cdt)
        x, aux = _ffn_residual(p_l, x, h, attn_o, cfg, cdt)
        K = jax.lax.dynamic_update_index_in_dim(K, k_l, i, 0)
        V = jax.lax.dynamic_update_index_in_dim(V, v_l, i, 0)
        return (x, K, V), aux

    (x, K, V), _ = jax.lax.scan(
        body, (x, paged.k, paged.v),
        (params["layers"], jnp.arange(L, dtype=jnp.int32)))
    x_last = jax.lax.dynamic_slice_in_dim(
        x, jnp.maximum(n_real - 1, 0), 1, axis=1)       # (1, 1, d)
    logits = unembed(params, cfg, x_last)
    return logits[:, -1], PagedState(k=K, v=V, pos=pos_blocks)
