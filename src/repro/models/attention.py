"""Attention for the distributed model path (pure JAX, compiles on any mesh).

Chunked online-softmax attention bounds activation memory at (S/chunk) x chunk
logits tiles — the same algorithm as kernels/flash_attention.py but expressed
in lax.scan so pjit can partition it (the Pallas kernel is the TPU-target
fast path, validated against the same oracle).

GQA uses an explicit q-head -> kv-head index map, which stays *exact* under
head padding (padded q heads read some kv head, and their out-projection rows
are zero-sliced).  Decode supports full and rolling-window KV caches.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import ArchConfig
from ..distributed.sharding import logical
from .layers import linear, linear_init, padded_heads, rope

NEG = -1e30


def kv_index_map(n_heads: int, n_kv: int, h_pad: int) -> np.ndarray:
    """q head -> kv head (padded q heads clamp to the last kv head)."""
    group = n_heads // n_kv
    idx = np.minimum(np.arange(h_pad) // group, n_kv - 1)
    return idx.astype(np.int32)


def attn_init(key, cfg: ArchConfig, *, cross: bool = False):
    d, hd, nkv = cfg.d_model, cfg.head_dim_, cfg.n_kv_heads
    hp = padded_heads(cfg)
    ks = jax.random.split(key, 4)
    bias = cfg.attn.qkv_bias
    return {
        "wq": linear_init(ks[0], d, hp * hd, ("embed", "heads"), bias=bias,
                          dtype=cfg.param_dtype),
        "wk": linear_init(ks[1], d, nkv * hd, ("embed", "kv"), bias=bias,
                          dtype=cfg.param_dtype),
        "wv": linear_init(ks[2], d, nkv * hd, ("embed", "kv"), bias=bias,
                          dtype=cfg.param_dtype),
        "wo": linear_init(ks[3], hp * hd, d, ("heads", "embed"),
                          scale=1.0 / math.sqrt(hp * hd),
                          dtype=cfg.param_dtype),
    }


def qkv_project(p, x, cfg: ArchConfig, positions, compute_dtype):
    """x: (B,S,d) -> q (B,S,Hp,hd), k/v (B,S,KV,hd), rope applied."""
    b, s, _ = x.shape
    hd, nkv = cfg.head_dim_, cfg.n_kv_heads
    hp = padded_heads(cfg)
    q = linear(p["wq"], x, compute_dtype).reshape(b, s, hp, hd)
    k = linear(p["wk"], x, compute_dtype).reshape(b, s, nkv, hd)
    v = linear(p["wv"], x, compute_dtype).reshape(b, s, nkv, hd)
    q = logical(q, "batch", None, "heads", None)
    k = logical(k, "batch", None, "kv", None)
    v = logical(v, "batch", None, "kv", None)
    if cfg.attn.rope_theta > 0:
        q = rope(q, positions, cfg.attn.rope_theta)
        k = rope(k, positions, cfg.attn.rope_theta)
    return q, k, v


class DecodeCache(NamedTuple):
    """Per-layer-stacked KV cache.  ``k``/``v``: (L, B, W, KV, hd); ``pos``:
    (L, B, W) absolute position of each slot (-1 = empty).  W is the full
    sequence budget, or the window size for sliding-window layers."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array


def init_cache(cfg: ArchConfig, batch: int, budget: int,
               dtype=jnp.bfloat16, n_layers: Optional[int] = None):
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    L = n_layers if n_layers is not None else cfg.n_layers
    w = min(budget, cfg.attn.window) if cfg.attn.window > 0 else budget
    shape = (L, batch, w, nkv, hd)
    return DecodeCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        pos=jnp.full((L, batch, w), -1, jnp.int32),
    )


def cache_spec_axes():
    return {"k": (None, "batch", None, "kv", None),
            "v": (None, "batch", None, "kv", None),
            "pos": (None, "batch", None)}


def update_cache_layer(k_layer, v_layer, pos_layer, k_new, v_new, positions):
    """Insert S new entries at slots positions % W (rolling).

    LOCKSTEP assumption: all sequences in the batch share the same position
    (static-batch serving, as in launch/serve.py), so the update is ONE
    contiguous dynamic_update_slice at a scalar start — a per-batch scatter
    here makes XLA SPMD re-gather the sharded cache (16 GB/chip of temps on
    decode_32k).  Writes never wrap: prefill fills [0, S) and decode writes
    a single slot.  positions: (B, S) absolute."""
    w = k_layer.shape[1]
    start = positions[0, 0] % w
    zero = jnp.zeros((), start.dtype)
    # the update must arrive batch-sharded/kv-replicated like the cache —
    # otherwise XLA reshards the whole (kvlen-sharded) cache per layer
    # (an all-to-all of GBs per decode step)
    k_new = logical(k_new, "batch", None, None, None)
    v_new = logical(v_new, "batch", None, None, None)
    k_layer = jax.lax.dynamic_update_slice(
        k_layer, k_new, (zero, start, zero, zero))
    v_layer = jax.lax.dynamic_update_slice(
        v_layer, v_new, (zero, start, zero, zero))
    pos_layer = jax.lax.dynamic_update_slice(
        pos_layer, positions, (zero, start))
    return k_layer, v_layer, pos_layer


# ---------------------------------------------------------------------------
# Paged KV cache (serving): block arena + per-slot block tables
# ---------------------------------------------------------------------------
#
# The serving arena carves one fixed (n_blocks, block_len, KV, hd) region per
# layer out of a global token budget; each batch slot owns an ordered list of
# block ids (its *block table*).  Because a slot fills its blocks strictly in
# order, gathering the table reconstructs a dense (W, KV, hd) view in which
# row p holds the slot's token at position p — so ``attend_decode`` (and its
# ``pos < 0`` empty-slot masking, the same path ragged cohort serving uses)
# works unchanged on the gathered view.  Block id 0 is a scratch block:
# inactive slots' writes land there and table entries < 0 gather it with
# their positions forced to -1, so garbage is never attended.


def gather_paged_view(k_blocks, v_blocks, pos_blocks, block_table):
    """Reassemble per-slot dense cache views from a block arena.

    k/v_blocks: (n_blocks, BL, KV, hd); pos_blocks: (n_blocks, BL);
    block_table: (B, MB) int32 with -1 marking unused entries.  Returns
    (k, v, pos) shaped (B, MB*BL, KV, hd) / (B, MB*BL); unused entries'
    positions are -1 so ``attend_decode`` masks them."""
    bt = jnp.maximum(block_table, 0)
    b, mb = block_table.shape
    bl = pos_blocks.shape[1]
    k = k_blocks[bt]                                     # (B, MB, BL, KV, hd)
    v = v_blocks[bt]
    pos = jnp.where((block_table >= 0)[:, :, None], pos_blocks[bt], -1)
    kv, hd = k.shape[-2:]
    return (k.reshape(b, mb * bl, kv, hd), v.reshape(b, mb * bl, kv, hd),
            pos.reshape(b, mb * bl))


def append_paged_layer(k_blocks, v_blocks, k_new, v_new, blk, off):
    """Write each slot's one new KV row into its current block.

    k/v_new: (B, 1, KV, hd); blk/off: (B,) target block id and row within
    it (inactive slots point at the scratch block 0)."""
    k_blocks = k_blocks.at[blk, off].set(k_new[:, 0])
    v_blocks = v_blocks.at[blk, off].set(v_new[:, 0])
    return k_blocks, v_blocks


def attend_paged(q, k_blocks, v_blocks, pos_blocks, block_table, idx_map, *,
                 q_position, window: int = 0,
                 scale: Optional[float] = None, global_flag=None):
    """Decode attention over a block arena: gather the slot's block table
    into a dense view, then run the standard masked decode attention."""
    k, v, pos = gather_paged_view(k_blocks, v_blocks, pos_blocks,
                                  block_table)
    return attend_decode(q, k, v, pos, idx_map, q_position=q_position,
                         window=window, scale=scale, global_flag=global_flag)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------

def attend_chunked(q, k, v, idx_map, *, causal: bool, window: int,
                   chunk: int, scale: Optional[float] = None,
                   global_flag=None):
    """q: (B,S,Hp,hd); k/v: (B,S,KV,hd).  Scans KV chunks, carrying
    (m, l, acc) for every query.  ``global_flag`` (scalar bool, may be
    traced) disables the sliding window for this layer (hymba's hybrid
    global/local mix inside one scan)."""
    b, s, hp, hd = q.shape
    nkv = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    chunk = min(chunk, s)
    while s % chunk:        # largest divisor of s not exceeding the request
        chunk -= 1
    n_chunks = s // chunk
    # matmuls run at the INPUT dtype (bf16 in the model path) with fp32
    # accumulation — flash-attention numerics; softmax state stays fp32
    qf = q * jnp.asarray(scale, q.dtype)
    q_pos = jnp.arange(s, dtype=jnp.int32)

    kc = k.reshape(b, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk

    def body(carry, xs):
        m, l, acc = carry
        k_ch, v_ch, start = xs
        k_rep = jnp.take(k_ch, idx_map, axis=2)               # (B,c,Hp,hd)
        v_rep = jnp.take(v_ch, idx_map, axis=2)
        logits = jnp.einsum("bqhd,bchd->bhqc", qf, k_rep,
                            preferred_element_type=jnp.float32)  # (B,Hp,S,c)
        kv_pos = start + jnp.arange(chunk, dtype=jnp.int32)
        mask = jnp.ones((s, chunk), dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            wmask = kv_pos[None, :] > q_pos[:, None] - window
            if global_flag is not None:
                wmask = wmask | global_flag
            mask &= wmask
        logits = jnp.where(mask[None, None], logits, NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))      # (B,Hp,S)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p.astype(v_rep.dtype), v_rep,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hp, s), NEG, jnp.float32)
    l0 = jnp.zeros((b, hp, s), jnp.float32)
    a0 = jnp.zeros((b, hp, s, hd), jnp.float32)
    # checkpoint the KV-chunk body: the (B,H,S,chunk) logits/probs are
    # recomputed in the backward instead of residual-stacked over chunks
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)          # (B,S,Hp,hd)


# ---------------------------------------------------------------------------
# Decode attention (one new token against the cache)
# ---------------------------------------------------------------------------

def attend_decode(q, k_cache, v_cache, pos_cache, idx_map, *,
                  q_position, window: int = 0,
                  scale: Optional[float] = None, global_flag=None):
    """q: (B,1,Hp,hd); caches: (B,W,KV,hd); pos_cache: (B,W) absolute
    positions (-1 empty).  q_position: (B,) absolute position of the query."""
    b, _, hp, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q[:, 0] * jnp.asarray(scale, q.dtype)                # (B,Hp,hd)
    k_rep = jnp.take(k_cache, idx_map, axis=2)                # (B,W,Hp,hd)
    v_rep = jnp.take(v_cache, idx_map, axis=2)
    # keep the cache-length sharding through the GQA gather (without this
    # XLA un-shards W and the decode_32k repeat costs 8.6 GB/chip)
    k_rep = logical(k_rep, "batch", "kvlen", None, None)
    v_rep = logical(v_rep, "batch", "kvlen", None, None)
    logits = jnp.einsum("bhd,bwhd->bhw", qf, k_rep,
                        preferred_element_type=jnp.float32)
    logits = logical(logits, "batch", None, "kvlen")
    mask = (pos_cache >= 0) & (pos_cache <= q_position[:, None])
    if window > 0:
        wmask = pos_cache > (q_position[:, None] - window)
        if global_flag is not None:
            wmask = wmask | global_flag
        mask &= wmask
    logits = jnp.where(mask[:, None, :], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhw,bwhd->bhd", p.astype(v_rep.dtype), v_rep,
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(q.dtype)                       # (B,1,Hp,hd)


def attend_prefix(q, k_cache, v_cache, pos_cache, idx_map, *,
                  q_positions, window: int = 0,
                  scale: Optional[float] = None, global_flag=None):
    """Prefill-chunk attention: C queries per batch row over a cache view.

    q: (B,C,Hp,hd); caches: (B,W,KV,hd); pos_cache: (B,W) absolute
    positions (-1 empty); q_positions: (B,C) each query's absolute
    position.  Row c attends cache rows whose position is in
    [0, q_positions[c]] — which includes the chunk's own rows, written
    into the cache before this call.

    Deliberately a FULL masked softmax per query (not the online-softmax
    scan of ``attend_chunked``): every query reduces over the same fixed
    W regardless of how prefill was chunked, so per-row outputs are
    bit-identical across chunk sizes and shared-prefix admissions — the
    property the chunked-prefill equivalence tests pin."""
    b, c, hp, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q * jnp.asarray(scale, q.dtype)                      # (B,C,Hp,hd)
    k_rep = jnp.take(k_cache, idx_map, axis=2)                # (B,W,Hp,hd)
    v_rep = jnp.take(v_cache, idx_map, axis=2)
    k_rep = logical(k_rep, "batch", "kvlen", None, None)
    v_rep = logical(v_rep, "batch", "kvlen", None, None)
    logits = jnp.einsum("bchd,bwhd->bhcw", qf, k_rep,
                        preferred_element_type=jnp.float32)
    mask = (pos_cache[:, None, :] >= 0) \
        & (pos_cache[:, None, :] <= q_positions[:, :, None])  # (B,C,W)
    if window > 0:
        wmask = pos_cache[:, None, :] > (q_positions[:, :, None] - window)
        if global_flag is not None:
            wmask = wmask | global_flag
        mask &= wmask
    logits = jnp.where(mask[:, None, :, :], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhcw,bwhd->bchd", p.astype(v_rep.dtype), v_rep,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)                                # (B,C,Hp,hd)


def attn_out(p, attn_heads, cfg: ArchConfig, compute_dtype):
    b, s = attn_heads.shape[:2]
    flat = attn_heads.reshape(b, s, -1)
    out = linear(p["wo"], flat, compute_dtype)
    return logical(out, "batch", None, "residual")
