"""Mixture-of-Experts with expert parallelism.

Design (scales to qwen3-moe-235b on a 256-chip pod):

* Expert weights are stacked (E, d, ff) and sharded **two ways**: the expert
  dim over the "model" axis (expert parallelism, E/TP experts resident per
  chip) and the ff dim over the data axes (FSDP storage — 908 GB of fp32
  expert params for qwen3-moe would not fit per-chip otherwise).
* The block runs under ``jax.shard_map``: tokens arrive batch-sharded and
  model-replicated; each program all-gathers its local experts' ff shards
  (bf16) — the FSDP weight gather that XLA overlaps with compute — routes
  all local tokens, and dispatches *sort-based* (argsort by expert id +
  capacity clipping) into an (E_local, C, d) buffer: no O(T x E x C)
  one-hot dispatch tensors.
* Partial outputs psum over "model"; the backward pass reverses the gathers
  into reduce-scatters automatically.

Token-choice top-k routing with capacity factor + load-balance aux loss
(Switch-style).  Shared experts (qwen2-moe) fold into one fused dense MLP
(concatenated hidden = exact) with a sigmoid gate.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.config import ArchConfig
from ..distributed import sharding as shd
from ..distributed.sharding import Param, logical
from .layers import linear, linear_init


def moe_init(key, cfg: ArchConfig):
    d = cfg.d_model
    e = cfg.moe
    ks = jax.random.split(key, 6)
    n_e = padded_experts(cfg)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": Param(
            jax.random.normal(ks[0], (d, n_e), jnp.float32) * scale,
            ("embed", None))},
        "w_gate": Param(
            jax.random.normal(ks[1], (n_e, d, e.d_ff_expert), jnp.float32)
            * scale, ("experts", "embed", "expert_shard")),
        "w_up": Param(
            jax.random.normal(ks[2], (n_e, d, e.d_ff_expert), jnp.float32)
            * scale, ("experts", "embed", "expert_shard")),
        "w_down": Param(
            jax.random.normal(ks[3], (n_e, e.d_ff_expert, d), jnp.float32)
            / math.sqrt(e.d_ff_expert), ("experts", "expert_shard", "embed")),
    }
    if e.n_shared > 0:
        ff_shared = e.n_shared * e.d_ff_expert
        p["shared"] = {
            "gate": linear_init(ks[4], d, ff_shared, ("embed", "mlp")),
            "up": linear_init(ks[5], d, ff_shared, ("embed", "mlp")),
            "down": linear_init(jax.random.fold_in(ks[5], 1), ff_shared, d,
                                ("mlp", "embed")),
            "sgate": linear_init(jax.random.fold_in(ks[4], 1), d, 1,
                                 ("embed", None)),
        }
    return p


def padded_experts(cfg: ArchConfig) -> int:
    """Pad expert count to the EP degree (qwen2-moe: 60 -> 64 on TP=16);
    padded experts are masked to -inf router logits."""
    ep = shd.axis_size("experts")
    n = cfg.moe.n_experts
    return ((n + ep - 1) // ep) * ep if ep > 1 else n


def _local_moe(x_flat, router_w, w_gate, w_up, w_down, *, cfg: ArchConfig,
               n_experts_total: int, e_local: int, lo, compute_dtype):
    """Dispatch/compute/combine for the experts [lo, lo+e_local).

    x_flat: (T, d).  Returns (partial_out (T, d), aux_loss scalar)."""
    e = cfg.moe
    t = x_flat.shape[0]
    k = e.top_k

    # --- routing (replicated across the model axis; fp32)
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    valid_expert = jnp.arange(n_experts_total) < e.n_experts
    logits = jnp.where(valid_expert[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch): E * sum_e f_e * P_e
    f = jnp.zeros((n_experts_total,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0) / (t * k)
    pbar = probs.mean(axis=0)
    aux = e.n_experts * jnp.sum(f * pbar)

    # --- sort-based dispatch with capacity
    cap = max(int(math.ceil(t * k / e.n_experts * e.capacity_factor)), 4)
    flat_e = top_ids.reshape(-1)                               # (T*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)                                # stable
    counts = jnp.zeros((n_experts_total,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)

    in_local = (flat_e >= lo) & (flat_e < lo + e_local) & (pos < cap)
    slot = jnp.where(in_local, (flat_e - lo) * cap + pos, e_local * cap)

    # Inverted dispatch: scatter int32 token ids (T*k of them), then ONE
    # (El*C, d) gather — never materialises a (T*k, d) tensor (4.3 GB for
    # qwen3-moe prefill shards).
    slot_src = jnp.full((e_local * cap + 1,), t, jnp.int32).at[slot].set(
        flat_tok)[:-1]                                         # (El*C,)
    x_pad = jnp.concatenate(
        [x_flat.astype(compute_dtype), jnp.zeros((1, x_flat.shape[1]),
                                                 compute_dtype)])
    buf = x_pad[slot_src].reshape(e_local, cap, -1)            # (El, C, d)

    # --- expert FFN (swiglu)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)                  # (El, C, d)

    # --- combine, chunked over the k assignments (bounds transients to
    # (T, d) instead of (T*k, d))
    y_pad = jnp.concatenate(
        [y.reshape(e_local * cap, -1),
         jnp.zeros((1, y.shape[-1]), y.dtype)])                # sentinel row
    contrib = jnp.where(in_local, flat_w, 0.0).astype(compute_dtype)
    slot_tk = slot.reshape(t, k)
    w_tk = contrib.reshape(t, k)
    out = jnp.zeros_like(x_flat)
    for j in range(k):
        out = out + y_pad[slot_tk[:, j]] * w_tk[:, j:j + 1]
    return out, aux


def moe_apply(p, x, cfg: ArchConfig, compute_dtype=jnp.bfloat16):
    """x: (B, S, d) -> (out (B, S, d), aux_loss)."""
    b, s, d = x.shape
    e = cfg.moe
    rules = shd.current_rules()
    n_total = p["w_gate"].shape[0]

    if rules is None or rules.rules.get("experts") is None:
        # single-device / unsharded path
        out, aux = _local_moe(
            x.reshape(-1, d), p["router"]["w"],
            p["w_gate"].astype(compute_dtype),
            p["w_up"].astype(compute_dtype),
            p["w_down"].astype(compute_dtype),
            cfg=cfg, n_experts_total=n_total, e_local=n_total, lo=0,
            compute_dtype=compute_dtype)
        out = out.reshape(b, s, d)
    else:
        mesh = rules.mesh
        model_axis = rules.rules["experts"]
        batch_axes = rules.rules.get("batch")
        e_local = n_total // mesh.shape[model_axis]
        P = jax.sharding.PartitionSpec

        xs = P(batch_axes, None, None)
        wspec_g = P(model_axis, None, batch_axes)   # FSDP ff shard
        wspec_d = P(model_axis, batch_axes, None)

        def block(x_l, rw, wg, wu, wd):
            # FSDP all-gather of the local experts' ff shards (bf16)
            if batch_axes is not None:
                gather = functools.partial(
                    jax.lax.all_gather, axis_name=batch_axes, tiled=True)
            else:
                gather = lambda w, axis: w                    # noqa: E731
            wg = gather(wg.astype(compute_dtype), axis=2)
            wu = gather(wu.astype(compute_dtype), axis=2)
            wd = gather(wd.astype(compute_dtype), axis=1)
            rank = jax.lax.axis_index(model_axis)
            out, aux = _local_moe(
                x_l.reshape(-1, d), rw, wg, wu, wd, cfg=cfg,
                n_experts_total=n_total, e_local=e_local,
                lo=rank * e_local, compute_dtype=compute_dtype)
            out = jax.lax.psum(out, model_axis)
            aux = jax.lax.pmean(aux, model_axis)
            return out.reshape(x_l.shape), aux

        out, aux = jax.shard_map(
            block, mesh=mesh,
            in_specs=(xs, P(None, None), wspec_g, wspec_g, wspec_d),
            out_specs=(xs, P()),
            check_vma=False,
        )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])

    if e.n_shared > 0:
        sh = p["shared"]
        hidden = jax.nn.silu(linear(sh["gate"], x, compute_dtype)) * \
            linear(sh["up"], x, compute_dtype)
        hidden = logical(hidden, "batch", None, "mlp")
        shared_out = linear(sh["down"], hidden, compute_dtype)
        sgate = jax.nn.sigmoid(linear(sh["sgate"], x, jnp.float32))
        out = out + shared_out * sgate.astype(compute_dtype)
    return logical(out, "batch", None, "residual"), aux
