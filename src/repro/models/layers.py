"""Parameter-dict building blocks shared by every architecture.

Initializers return trees whose leaves are ``Param(value, logical_axes)``;
apply functions take the plain value trees.  Compute runs in ``cfg.dtype``
(bf16 by default) with fp32 norms/softmax and fp32 params.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.config import ArchConfig
from ..distributed.sharding import Param, logical, axis_size


def _dtype(name: str):
    return jnp.dtype(name)


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def padded_heads(cfg: ArchConfig) -> int:
    """q heads padded up to a multiple of the TP degree (exactness argument:
    padded heads' out-projection rows are sliced off the result)."""
    tp = axis_size("heads")
    return pad_to(cfg.n_heads, tp) if tp > 1 else cfg.n_heads


def padded_vocab(cfg: ArchConfig) -> int:
    tp = axis_size("vocab")
    return pad_to(cfg.vocab, tp * 128) if tp > 1 else pad_to(cfg.vocab, 128)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, axes: Tuple, *, bias: bool = False,
                scale: Optional[float] = None, dtype: str = "float32"):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), _dtype(dtype)) * scale
    p = {"w": Param(w, axes)}
    if bias:
        p["b"] = Param(jnp.zeros((d_out,), _dtype(dtype)), (axes[-1],))
    return p


def linear(p, x, compute_dtype=jnp.bfloat16):
    out = jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                     p["w"].astype(compute_dtype))
    if "b" in p:
        out = out + p["b"].astype(compute_dtype)
    return out


def norm_init(d: int, kind: str = "rmsnorm"):
    p = {"scale": Param(jnp.ones((d,), jnp.float32), ("embed",))}
    if kind == "layernorm":
        p["bias"] = Param(jnp.zeros((d,), jnp.float32), ("embed",))
    return p


def norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype: str = "float32"):
    # GPT-style 0.02 std — keeps tied-unembedding logits O(1) at init
    w = jax.random.normal(key, (vocab, d), _dtype(dtype)) * 0.02
    return {"emb": Param(w, ("vocab", "embed"))}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    out = jnp.take(p["emb"], tokens, axis=0).astype(compute_dtype)
    return logical(out, "batch", None, "residual")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"down": linear_init(ks[2], ff, d, ("mlp", "embed"),
                             dtype=cfg.param_dtype)}
    if cfg.act == "swiglu":
        p["gate"] = linear_init(ks[0], d, ff, ("embed", "mlp"),
                                dtype=cfg.param_dtype)
        p["up"] = linear_init(ks[1], d, ff, ("embed", "mlp"),
                              dtype=cfg.param_dtype)
    else:
        p["up"] = linear_init(ks[1], d, ff, ("embed", "mlp"),
                              dtype=cfg.param_dtype)
    return p


def mlp(p, x, act: str = "swiglu", compute_dtype=jnp.bfloat16):
    if act == "swiglu":
        h = jax.nn.silu(linear(p["gate"], x, compute_dtype)) * \
            linear(p["up"], x, compute_dtype)
    elif act == "gelu":
        h = jax.nn.gelu(linear(p["up"], x, compute_dtype))
    else:
        h = jax.nn.relu(linear(p["up"], x, compute_dtype))
    h = logical(h, "batch", None, "mlp")
    out = linear(p["down"], h, compute_dtype)
    return logical(out, "batch", None, "residual")
