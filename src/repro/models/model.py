"""Public model API: build_model(cfg) -> Model.

Model is a thin namespace of pure functions over plain param pytrees:
  init(key)                      -> annotated params (Param leaves)
  loss(params, batch)            -> (scalar loss, metrics dict)
  prefill(params, batch)         -> (last-token logits, State)
  decode_step(params, state, t)  -> (logits, State)

``batch`` is a dict: tokens (B,S) int32, labels (B,S) int32 (-1 = masked),
and optionally patches/frames (B,P,d) for the vlm/audio stubs.  The loss is
vocab-parallel: the (B,S,V) logits stay sharded over the "vocab" axis and the
reduction happens on the sharded dim (never materialising a replicated 4 GB
logits tensor for 256k vocabs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.config import ArchConfig
from ..distributed.sharding import logical, split_tree
from . import encdec as encdec_mod
from . import transformer as tfm


def cross_entropy(logits, labels, vocab: int):
    """logits: (B,S,Vp) fp32 vocab-sharded; labels: (B,S) with -1 masked.
    Returns (sum_loss, n_tokens)."""
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + lmax[..., 0]
    safe_labels = jnp.maximum(labels, 0)
    lbl = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab)
    losses = jnp.where(mask, lse - lbl, 0.0)
    return jnp.sum(losses), jnp.sum(mask)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    forward: Callable
    # paged decode over a block-arena KV cache (repro.serve continuous
    # batching); None for families whose decode state a block arena
    # cannot hold (ssm/hybrid/encdec)
    decode_paged: Optional[Callable] = None
    # one prefill chunk for a single slot over the block arena (chunked
    # prefill / prefix sharing); None wherever decode_paged is None, and
    # also for vlm (patch rows cannot be chunk-aligned)
    prefill_chunk: Optional[Callable] = None


def build_model(cfg: ArchConfig) -> Model:
    if cfg.is_encdec:
        return encdec_mod.build_encdec(cfg)

    def init(key):
        return tfm.transformer_init(key, cfg)

    def loss(params, batch, *, remat: bool = True):
        patches = batch.get("patches")
        logits, _, aux = tfm.forward(
            params, cfg, batch["tokens"], patches=patches, mode="train",
            remat=remat)
        labels = batch["labels"]
        if patches is not None and cfg.n_patches > 0:
            # patch positions carry no LM loss
            pad = jnp.full(labels.shape[:1] + (cfg.n_patches,), -1,
                           labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        total, n = cross_entropy(logits, labels, cfg.vocab)
        ce = total / jnp.maximum(n, 1)
        aux_w = cfg.moe.router_aux_weight if cfg.moe.enabled else 0.0
        metrics = {"ce": ce, "aux": aux, "tokens": n}
        return ce + aux_w * aux, metrics

    def forward(params, batch):
        logits, _, _ = tfm.forward(
            params, cfg, batch["tokens"], patches=batch.get("patches"),
            mode="train", remat=False)
        return logits

    def prefill(params, batch, budget=None):
        logits, state, _ = tfm.forward(
            params, cfg, batch["tokens"], patches=batch.get("patches"),
            mode="prefill", budget=budget)
        return logits[:, -1], state

    def decode_step(params, state, tokens):
        """tokens: (B, 1) int32 -> (logits (B, vocab_p), new state)."""
        logits, state, _ = tfm.forward(
            params, cfg, tokens, mode="decode", state=state)
        return logits[:, -1], state

    decode_paged = None
    prefill_chunk = None
    if cfg.family in tfm.PAGED_FAMILIES:
        def decode_paged(params, paged, tokens, block_table, slot_pos):
            """tokens: (B, 1); block_table: (B, MB); slot_pos: (B,) ->
            (logits (B, vocab_p), new PagedState)."""
            return tfm.forward_paged_decode(params, cfg, tokens, paged,
                                            block_table, slot_pos)

        if cfg.n_patches == 0:
            def prefill_chunk(params, paged, tokens, block_table, start,
                              n_real):
                """tokens: (1, C); block_table: (1, MB); start/n_real: ()
                -> (logits (1, vocab_p), new PagedState)."""
                return tfm.forward_paged_chunk(params, cfg, tokens, paged,
                                               block_table, start, n_real)

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, forward=forward,
                 decode_paged=decode_paged, prefill_chunk=prefill_chunk)
