"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<k>/arrays.npz + meta.json; a top-level LATEST file is
updated atomically (write-tmp + rename) only after the step directory is
fully written, so a preemption mid-save can never corrupt the restore path.

Elastic restore: arrays are saved as full (host-gathered) values keyed by
tree path; ``restore`` device_puts them under *target* shardings — which may
belong to a different mesh than the one that saved (scale up/down, swap a
failed pod).  Training is deterministic from (checkpoint, data seed), so an
elastic restart reproduces the same trajectory.

Saves can run on a background thread (``async_save=True``): the paper's
Overlap pattern applied to checkpoint I/O — step t+1 computes while step t's
state streams to disk (state is snapshotted to host first, so there is no
torn read).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree, extra_meta: Optional[dict] = None):
        """Snapshot to host, then write (async if configured)."""
        host = jax.tree.map(lambda t: np.asarray(jax.device_get(t)), tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra_meta),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra_meta)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra_meta):
        flat = _flatten(host_tree)
        step_dir = os.path.join(self.dir, f"step_{step:08d}")
        tmp_dir = step_dir + ".tmp"
        shutil.rmtree(tmp_dir, ignore_errors=True)
        os.makedirs(tmp_dir, exist_ok=True)
        np.savez(os.path.join(tmp_dir, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        meta = {"step": step, "time": time.time(),
                "keys": sorted(flat.keys())}
        meta.update(extra_meta or {})
        with open(os.path.join(tmp_dir, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(step_dir, ignore_errors=True)
        os.rename(tmp_dir, step_dir)
        # atomically advance LATEST
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(f"step_{step:08d}")
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``tree_like``.  ``shardings`` (same
        structure, NamedSharding leaves) enables elastic placement onto any
        mesh; None restores as ordinary host-local arrays."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        flat_like = _flatten(tree_like)
        missing = [k for k in flat_like if k not in data.files]
        if missing:
            raise KeyError(f"checkpoint missing keys: {missing[:5]} "
                           f"({len(missing)} total)")
        flat_shard = _flatten(shardings) if shardings is not None else None

        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        keys = [SEP.join(_path_str(p) for p in path_)
                for path_, _ in
                jax.tree_util.tree_flatten_with_path(tree_like)[0]]
        out = []
        for k in keys:
            arr = data[k]
            if flat_shard is not None:
                out.append(jax.device_put(arr, flat_shard[k]))
            else:
                out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    def meta(self, step: Optional[int] = None) -> dict:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self.dir, f"step_{step:08d}",
                               "meta.json")) as f:
            return json.load(f)
